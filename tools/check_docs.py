"""Docs checker: keep README/DESIGN/docs fences executable + links live.

    PYTHONPATH=src python tools/check_docs.py

Three passes over every tracked ``*.md`` file:

1. **intra-repo links** — every relative markdown link target must
   exist (anchors stripped; http(s)/mailto links skipped);
2. **fence syntax** — every ````bash`` fence must pass ``bash -n``,
   every ````python`` fence must byte-compile;
3. **marked fences run** — a fence immediately preceded by an
   ``<!-- docs-ci: run -->`` comment is executed with a timeout (the
   README quickstart, so the documented commands can never rot).

Exit status is the number of failures (0 = clean).
"""
from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
RUN_MARKER = "<!-- docs-ci: run -->"
RUN_TIMEOUT_S = 300

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(\w*)\s*$")


def md_files() -> list[Path]:
    out = subprocess.run(
        ["git", "ls-files", "-co", "--exclude-standard", "*.md"],
        cwd=REPO, capture_output=True, text=True, check=True)
    return [REPO / p for p in out.stdout.split()]


def iter_fences(text: str):
    """Yield (language, body, line_number, marked_run) per code fence."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE_RE.match(lines[i])
        if not m:
            i += 1
            continue
        lang, start = m.group(1), i
        body: list[str] = []
        i += 1
        while i < len(lines) and not lines[i].startswith("```"):
            body.append(lines[i])
            i += 1
        i += 1  # closing fence
        marked = start > 0 and lines[start - 1].strip() == RUN_MARKER
        yield lang, "\n".join(body) + "\n", start + 1, marked


def check_links(path: Path, text: str) -> list[str]:
    bad = []
    # fences often contain shell-ish [x](y)-looking text: strip them first
    prose = re.sub(r"```.*?```", "", text, flags=re.S)
    for target in LINK_RE.findall(prose):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if rel and not (path.parent / rel).exists():
            bad.append(f"{path.relative_to(REPO)}: broken link -> {target}")
    return bad


def check_fence(path: Path, lang: str, body: str, line: int,
                marked: bool) -> list[str]:
    where = f"{path.relative_to(REPO)}:{line}"
    if lang == "bash":
        r = subprocess.run(["bash", "-n"], input=body, capture_output=True,
                           text=True)
        if r.returncode:
            return [f"{where}: bash fence fails syntax check: "
                    f"{r.stderr.strip()}"]
    elif lang == "python":
        try:
            compile(body, where, "exec")
        except SyntaxError as e:
            return [f"{where}: python fence fails to compile: {e}"]
    if marked:
        if lang != "bash":
            return [f"{where}: only bash fences can be marked "
                    f"'{RUN_MARKER}'"]
        r = subprocess.run(["bash", "-euo", "pipefail", "-c", body],
                           cwd=REPO, capture_output=True, text=True,
                           timeout=RUN_TIMEOUT_S)
        if r.returncode:
            return [f"{where}: marked fence exited {r.returncode}:\n"
                    f"{r.stdout}{r.stderr}"]
        print(f"ran {where}:\n{r.stdout}", end="")
    return []


def main() -> int:
    failures: list[str] = []
    files = md_files()
    n_fences = n_ran = 0
    for path in files:
        text = path.read_text()
        failures += check_links(path, text)
        for lang, body, line, marked in iter_fences(text):
            if lang in ("bash", "python"):
                n_fences += 1
                n_ran += marked
                failures += check_fence(path, lang, body, line, marked)
    print(f"checked {len(files)} md files, {n_fences} fences "
          f"({n_ran} executed)")
    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    return len(failures)


if __name__ == "__main__":
    sys.exit(main())
