"""Sharded checkpointing: npz-per-leaf + JSON manifest, async writer,
keep-last-k retention, and reshard-on-load (elastic rescale).

Design (orbax is unavailable offline; this is the same layout in miniature):

    <dir>/step_<N>/
        manifest.json     {step, leaf paths, shapes, dtypes, tree structure}
        arrays.npz        one entry per flattened leaf

On load, every leaf is ``device_put`` against the *target* sharding — a
checkpoint written on a (2,16,16) mesh restores onto (16,16) or a host mesh
unchanged (elastic scaling / shrink-on-failure).  Writes happen on a
background thread (training continues; ``wait()`` joins before the next
save — async checkpointing).  fp32/bf16 conversions are explicit.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str | Path, step: int, tree, *,
                    keep: int | None = None) -> Path:
    """Blocking save.  Returns the step directory."""
    directory = Path(directory)
    step_dir = directory / f"step_{step:09d}"
    tmp = directory / f".tmp_step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    paths, leaves, treedef = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "time": time.time(), "leaves": []}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            arrays[f"leaf_{i}"] = arr.view(np.uint16)
            dtype = "bfloat16"
        else:
            arrays[f"leaf_{i}"] = arr
            dtype = str(arr.dtype)
        manifest["leaves"].append(
            {"path": p, "key": f"leaf_{i}", "dtype": dtype,
             "shape": list(arr.shape)})
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if step_dir.exists():
        shutil.rmtree(step_dir)
    tmp.rename(step_dir)       # atomic publish
    if keep:
        _retain(directory, keep)
    return step_dir


def _retain(directory: Path, keep: int):
    steps = sorted(d for d in directory.glob("step_*") if d.is_dir())
    for d in steps[:-keep]:
        shutil.rmtree(d)


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    steps = sorted(directory.glob("step_*"))
    return int(steps[-1].name.split("_")[1]) if steps else None


def load_checkpoint(directory: str | Path, like_tree, *, step: int | None = None,
                    shardings=None):
    """Restore into the structure of ``like_tree``; leaves are device_put
    against ``shardings`` (same treedef) when given — reshard-on-load."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    step_dir = directory / f"step_{step:09d}"
    manifest = json.loads((step_dir / "manifest.json").read_text())
    data = np.load(step_dir / "arrays.npz")

    paths, leaves, treedef = _flatten_with_paths(like_tree)
    by_path = {m["path"]: m for m in manifest["leaves"]}
    shard_leaves = (jax.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else [None] * len(leaves))

    out = []
    for p, like, sh in zip(paths, leaves, shard_leaves):
        m = by_path[p]
        arr = data[m["key"]]
        if m["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"shape mismatch at {p}: {arr.shape} vs "
                             f"{like.shape}")
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest["step"]


class CheckpointManager:
    """Async keep-k checkpointing driver used by the train loop."""

    def __init__(self, directory: str | Path, *, keep: int = 3,
                 every: int = 100):
        self.directory = Path(directory)
        self.keep = keep
        self.every = every
        self._thread: threading.Thread | None = None

    def maybe_save(self, step: int, tree) -> bool:
        if step % self.every:
            return False
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=save_checkpoint,
            args=(self.directory, step, host_tree),
            kwargs={"keep": self.keep}, daemon=True)
        self._thread.start()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, like_tree, shardings=None):
        return load_checkpoint(self.directory, like_tree,
                               shardings=shardings)
