from repro.checkpoint.checkpoint import (  # noqa: F401
    save_checkpoint, load_checkpoint, latest_step, CheckpointManager)
