"""Live training: online async-local SGD that trains while serving.

The continual-learning layer over the repo's three existing pillars —
the replica-merge SGD engine (:mod:`repro.core.sgd`), the fault/
staleness gate (:mod:`repro.train.fault`), and the atomic-hot-swap
scoring engine (:mod:`repro.serve.glm`):

* :mod:`repro.live.stream`  — deterministic seedable minibatch streams
  (synthetic planted-GLM + replayable chunked libsvm);
* :mod:`repro.live.learner` — the replica-merge loop with liveness
  masking, kill/revive, optional int8 error-feedback merge compression,
  and kernel-dispatch replica passes;
* :mod:`repro.live.publish` — staleness-bounded snapshot publishing
  into the scoring engine, step-stamped per snapshot.

See docs/LIVE.md for the architecture and `benchmarks/bench_live.py`
for the measured convergence-vs-wall-time / latency-under-training
cells.
"""
from repro.live.learner import LiveConfig, LiveLearner
from repro.live.publish import SnapshotPublisher
from repro.live.stream import LibsvmStream, StreamBatch, SyntheticStream

__all__ = [
    "LiveConfig",
    "LiveLearner",
    "LibsvmStream",
    "SnapshotPublisher",
    "StreamBatch",
    "SyntheticStream",
]
