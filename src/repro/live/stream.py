"""Streaming minibatch sources for the live (train-while-serving) loop.

A *stream* is an unbounded, deterministic sequence of fixed-shape
minibatches: the continual-learning analogue of the closed epochs the
study engine runs.  Two sources:

* :class:`SyntheticStream` — a seedable generator over a *stationary*
  planted-GLM distribution (one ``w*`` per stream seed, fresh examples
  per chunk).  Chunk ``i`` is a pure function of ``(seed, i)``, so two
  streams with the same config replay byte-identical batches — replays,
  fault-injection re-runs, and benchmark re-runs all see the same data.
* :class:`LibsvmStream` — a replayable chunked reader over the ingest
  layer's libsvm parser (:mod:`repro.data.ingest.libsvm`): fixed-size
  row chunks converted to padded ELL with a pinned feature width, so a
  file larger than memory streams through the learner.  ``loop=True``
  wraps around at EOF (the continual setting re-visits the data).

Both yield :class:`StreamBatch` — ELL ``values/indices`` plus labels,
and a dense view for dense-profile streams — at one fixed shape, so the
learner's jitted replica epoch never re-traces.  Per-replica partition
assignment reuses :func:`repro.core.sgd.partition_indices` (the paper's
row-rr / row-ch access paths + rep-k halos apply unchanged to a chunk).
"""
from __future__ import annotations

import dataclasses
import itertools
from pathlib import Path
from typing import Iterator

import numpy as np

from repro.core import sparse as sparse_mod
from repro.data.ingest import libsvm


@dataclasses.dataclass(frozen=True)
class StreamBatch:
    """One fixed-shape minibatch of a live stream.

    ``seq`` is the monotone chunk index (0, 1, 2, ...) — the learner's
    data clock.  ``values``/``indices`` are zero-padded ELL ``[n, K]``;
    ``X`` is the dense ``[n, d]`` view for dense streams (None for
    sparse ones).  Labels ``y`` are in {-1, +1}.
    """

    seq: int
    values: np.ndarray          # [n, K] float32
    indices: np.ndarray         # [n, K] int32
    y: np.ndarray               # [n] float32
    X: np.ndarray | None = None  # [n, d] float32 (dense streams only)

    @property
    def n(self) -> int:
        return len(self.y)


class SyntheticStream:
    """Deterministic infinite stream over one planted GLM distribution.

    ``w*`` (and the Zipfian feature popularity for sparse profiles) is
    drawn once from ``seed``; chunk ``i`` draws its examples from
    ``default_rng([seed, 1 + i])`` — a pure function of the pair, so
    ``batch(i)`` is random-access and ``reset()`` is free.  ``dense=True``
    produces Gaussian dense rows (ELL view = all ``d`` columns per row);
    the default is the sparse profile (lognormal nnz/row, Zipf columns)
    matching :func:`repro.data.synthetic.make_sparse`.
    """

    def __init__(self, *, n_batch: int, d: int, seed: int = 0,
                 dense: bool = False, avg_nnz: float = 4.0,
                 max_nnz: int = 8, noise: float = 0.05):
        if n_batch < 1 or d < 1:
            raise ValueError(f"n_batch/d must be >= 1: {n_batch}, {d}")
        self.n_batch = n_batch
        self.d = d
        self.seed = seed
        self.dense = dense
        self.noise = noise
        self.max_nnz = min(max_nnz, d) if not dense else d
        self.avg_nnz = min(avg_nnz, float(self.max_nnz))
        rng = np.random.default_rng(seed)
        if dense:
            self.w_star = rng.normal(0, 1, d).astype(np.float32)
            self._probs = None
        else:
            ranks = np.arange(1, d + 1, dtype=np.float64)
            probs = 1.0 / ranks
            self._probs = probs / probs.sum()
            self.w_star = (rng.normal(0, 1, d) / np.sqrt(ranks)) \
                .astype(np.float32)

    @property
    def ell_width(self) -> int:
        return self.max_nnz

    def batch(self, seq: int) -> StreamBatch:
        """Chunk ``seq`` — pure function of ``(seed, seq)``."""
        rng = np.random.default_rng([self.seed, 1 + seq])
        n = self.n_batch
        if self.dense:
            X = rng.normal(0, 1, (n, self.d)).astype(np.float32)
            margins = X @ self.w_star
            y = _flip(rng, margins, self.noise)
            return StreamBatch(seq, X.copy(), _dense_indices(n, self.d),
                               y, X=X)
        K = self.max_nnz
        mu = np.log(max(self.avg_nnz, 1.5))
        nnz = np.clip(rng.lognormal(mu, 0.8, n), 1, K).astype(np.int64)
        values = np.zeros((n, K), np.float32)
        indices = np.zeros((n, K), np.int32)
        margins = np.zeros(n, np.float64)
        for i in range(n):
            idx = np.unique(rng.choice(self.d, int(nnz[i]), p=self._probs))
            val = rng.normal(0, 1, len(idx)).astype(np.float32)
            values[i, :len(idx)] = val
            indices[i, :len(idx)] = idx
            margins[i] = float(val @ self.w_star[idx])
        y = _flip(rng, margins, self.noise)
        return StreamBatch(seq, values, indices, y)

    def __iter__(self) -> Iterator[StreamBatch]:
        for i in itertools.count():
            yield self.batch(i)

    def holdout(self, n: int = 512, *, seq: int = -1):
        """A fixed evaluation set drawn outside the training chunks
        (chunk index ``-1`` never appears in the stream) — returns
        ``(ELLMatrix, y)`` for :func:`repro.core.sparse.loss`."""
        saved = self.n_batch
        try:
            self.n_batch = n
            b = self.batch(seq)
        finally:
            self.n_batch = saved
        ell = sparse_mod.ELLMatrix(
            *_to_jnp(b.values, b.indices), self.d)
        return ell, b.y


class LibsvmStream:
    """Replayable chunked reader: libsvm text -> fixed-shape ELL batches.

    Rows stream through :func:`repro.data.ingest.libsvm.iter_rows`
    (bz2-transparent, comment/qid-robust) in chunks of ``n_batch``;
    each chunk converts to padded ELL at the pinned ``(d, ell_width)``.
    The tail chunk short of ``n_batch`` rows is dropped — live batches
    must hold one jit-stable shape.  ``loop=True`` restarts at EOF so
    the stream is unbounded (``seq`` keeps increasing across wraps);
    ``loop=False`` raises ``StopIteration`` at EOF.

    Indices follow the libsvm 1-based convention; ``zero_based=True``
    reads them as 0-based (chunked streaming cannot afford the ingest
    layer's whole-file base auto-detection).
    """

    def __init__(self, path: str | Path, *, n_batch: int, d: int,
                 ell_width: int, loop: bool = True,
                 zero_based: bool = False, labels_01: bool | None = None):
        self.path = Path(path)
        self.n_batch = n_batch
        self.d = d
        self.ell_width = ell_width
        self.loop = loop
        self.zero_based = zero_based
        self.labels_01 = labels_01
        self._rows: Iterator | None = None
        self._seq = 0

    dense = False

    def _open(self):
        import bz2
        opener = bz2.open if self.path.suffix == ".bz2" else open
        self._fh = opener(self.path, "rt")
        return libsvm.iter_rows(self._fh)

    def reset(self) -> None:
        """Rewind to the start of the file (``seq`` keeps counting)."""
        self._rows = None

    def batch(self) -> StreamBatch:
        """The next chunk of ``n_batch`` rows (wrapping at EOF if
        ``loop``); raises ``StopIteration`` when the file is exhausted
        and ``loop=False``."""
        if self._rows is None:
            self._rows = self._open()
        values = np.zeros((self.n_batch, self.ell_width), np.float32)
        indices = np.zeros((self.n_batch, self.ell_width), np.int32)
        y = np.zeros(self.n_batch, np.float32)
        got = 0
        while got < self.n_batch:
            try:
                label, idx, val = next(self._rows)
            except StopIteration:
                if not self.loop:
                    raise
                self._rows = self._open()
                continue
            if not self.zero_based:
                if len(idx) and int(idx[0]) == 0:
                    raise libsvm.LibsvmFormatError(
                        f"{self.path}: feature index 0 in a 1-based "
                        f"stream; pass zero_based=True")
                idx = idx - 1
            if len(idx) and int(idx[-1]) >= self.d:
                raise libsvm.LibsvmFormatError(
                    f"{self.path}: feature index {int(idx[-1])} out of "
                    f"range for d={self.d}")
            k = min(len(idx), self.ell_width)
            values[got, :k] = val[:k]
            indices[got, :k] = idx[:k]
            y[got] = label
            got += 1
        if self.labels_01 or (self.labels_01 is None and (y >= 0).all()):
            y = np.where(y > 0, 1.0, -1.0).astype(np.float32)
        b = StreamBatch(self._seq, values, indices, y)
        self._seq += 1
        return b

    def __iter__(self) -> Iterator[StreamBatch]:
        while True:
            try:
                yield self.batch()
            except StopIteration:
                return


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _flip(rng, margins, noise) -> np.ndarray:
    y = np.where(margins >= 0, 1.0, -1.0)
    y[rng.random(len(y)) < noise] *= -1.0
    return y.astype(np.float32)


def _dense_indices(n: int, d: int) -> np.ndarray:
    return np.broadcast_to(np.arange(d, dtype=np.int32), (n, d)).copy()


def _to_jnp(values, indices):
    import jax.numpy as jnp

    return jnp.asarray(values), jnp.asarray(indices)
