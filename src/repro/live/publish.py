"""Staleness-bounded snapshot publishing: learner -> scoring engine.

The bridge between the two halves of the live loop.  The learner merges
every ``merge_every`` steps; the publisher ships every ``every_merges``-th
merged model into :meth:`repro.serve.glm.GLMScoreEngine.swap_model` —
one atomic reference assignment, so the serving path never observes a
torn model and every response stays consistent with exactly one
snapshot.  Each snapshot is stamped with the learner step that produced
it (``ModelSnapshot.step``), which makes staleness *measurable*: at any
moment, ``learner.steps - engine.model.step`` is how far the served
model lags training, and :meth:`SnapshotPublisher.bound_steps` is the
guaranteed ceiling (``every_merges * merge_every`` steps) as long as the
publisher is attached and merges are not being skipped (at least one
replica alive).

Publishes emit ``live.publish`` spans and a ``live.publishes`` counter,
completing the single-timeline story: ``live.step`` -> ``live.merge`` ->
``live.publish`` -> ``serve.batch`` in one Perfetto trace.
"""
from __future__ import annotations

from repro.obs import metrics, trace
from repro.serve.glm import GLMScoreEngine, ModelSnapshot


class SnapshotPublisher:
    """Publishes every ``every_merges``-th merged model to the engine.

    Attach with ``learner.add_merge_hook(publisher.on_merge)`` (or call
    :meth:`attach`).  ``history`` records ``(version, step, merge)`` per
    publish — the audit trail the chaos tests and the live benchmark
    check response versions against.
    """

    def __init__(self, engine: GLMScoreEngine, *, every_merges: int = 1):
        if every_merges < 1:
            raise ValueError(f"every_merges must be >= 1: {every_merges}")
        self.engine = engine
        self.every_merges = every_merges
        self.publishes = 0
        #: optional HealthMonitor (set by monitor.watch_live): publishes
        #: are reported so a stalled publisher is visible as silence
        self.monitor = None
        #: per-publish audit rows: {"version", "step", "merge"}
        self.history: list[dict] = []

    def attach(self, learner) -> "SnapshotPublisher":
        learner.add_merge_hook(self.on_merge)
        return self

    def on_merge(self, learner) -> ModelSnapshot | None:
        """Merge hook: publish when the merge count hits the period.

        Returns the published snapshot, or None when this merge is
        between publish points.
        """
        if learner.merges % self.every_merges:
            return None
        with trace.span("live.publish", step=learner.steps,
                        merge=learner.merges):
            snap = self.engine.swap_model(learner.merged_model,
                                          step=learner.steps)
        self.publishes += 1
        metrics.counter("live.publishes").inc()
        self.history.append({"version": snap.version, "step": learner.steps,
                             "merge": learner.merges})
        if self.monitor is not None:
            self.monitor.on_publish(version=snap.version, step=learner.steps)
        return snap

    @property
    def last(self) -> dict | None:
        return self.history[-1] if self.history else None

    def bound_steps(self, merge_every: int) -> int:
        """The staleness ceiling in learner steps: once the first
        snapshot is out, the served model never lags the newest merged
        model by more than ``every_merges * merge_every`` steps
        (provided merges are not skipped — i.e. >= 1 replica alive)."""
        return self.every_merges * merge_every

    def staleness(self, learner) -> int | None:
        """Current lag in learner steps of the *published* model behind
        the learner (None before the first publish)."""
        snap = self.engine.model
        if snap.step is None:
            return None
        return learner.steps - snap.step
