"""The live async-local SGD learner: replica-merge over a stream.

This is the paper's §5.1 replica-merge scheme (the offline
``AsyncLocalSGD`` engine in :mod:`repro.core.sgd`) lifted to the
continual setting: instead of closed epochs over a frozen dataset, the
learner consumes an unbounded :mod:`repro.live.stream` minibatch
sequence, runs one *local* pass per replica per stream step, and merges
the replicas every ``merge_every`` steps.  Three pieces the offline
engine does not have, all previously dead code, are wired in:

* **bounded-staleness fault masking** — the merge averages only the
  replicas :class:`repro.train.fault.MergeGate` reports alive; a dead
  replica's model is frozen (it computes nothing) and dropped from the
  mean, and on revival it is re-seeded from the latest merged model —
  the paper's straggler insight applied to failures: a dead pod degrades
  the merge, never halts the stream;
* **error-feedback compressed merges** — ``compress=True`` exchanges
  int8-quantized per-replica *deltas* from the last merged anchor
  (:mod:`repro.optim.compress`, the Keuper & Pfreundt / Buckwild
  low-precision idea at the expensive interconnect boundary), with a
  persistent per-replica error-feedback buffer so the merged model stays
  unbiased over time;
* **kernel dispatch** — replica passes route through ``glm_sgd`` /
  ``glm_sgd_sparse`` / ``glm_sparse`` exactly like the offline engine
  (``kernel_backend=None`` keeps the pure-XLA path), vmapped over the
  replica axis, jitted once: stream batches hold one shape by contract.

Every step/merge emits ``live.step`` / ``live.merge`` spans and
``live.*`` counters, so a traced run renders the learner next to the
serving engine's ``serve.*`` spans on one timeline (docs/LIVE.md).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import glm, sparse
from repro.core.sgd import partition_indices
from repro.live.stream import StreamBatch
from repro.obs import metrics, trace
from repro.optim import compress as C
from repro.train.fault import Heartbeat, MergeGate

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LiveConfig:
    """Knobs of the live loop (the offline ``AsyncLocalSGD`` axes plus
    the staleness/compression knobs the continual setting adds).

    replicas        R model replicas (paper's model-replication axis).
    step_size       SGD step alpha (constant; streams are unbounded).
    local_batch     per-replica update granularity (1 = incremental).
    merge_every     merge period in *stream steps* (staleness knob #1).
    access/rep_k    example->replica assignment within a chunk
                    (row-rr / row-ch + halos), as in the offline engine.
    compress        int8 error-feedback delta exchange at merges.
    kernel_backend  kernel dispatch registry backend (None = pure XLA).
    timeout_s       heartbeat staleness bound for the merge gate.
    """

    task: str = "lr"
    replicas: int = 4
    step_size: float = 0.05
    local_batch: int = 1
    merge_every: int = 4
    access: Literal["round_robin", "chunk"] = "chunk"
    rep_k: int = 0
    compress: bool = False
    kernel_backend: str | None = None
    timeout_s: float = 60.0

    def __post_init__(self):
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1: {self.replicas}")
        if self.merge_every < 1:
            raise ValueError(f"merge_every must be >= 1: {self.merge_every}")
        if self.local_batch < 1:
            raise ValueError(f"local_batch must be >= 1: {self.local_batch}")


class LiveLearner:
    """Replica-merge SGD over a live stream — see the module docstring.

    The learner is single-threaded by design (call :meth:`step` from one
    thread); concurrency with the serving path happens through the
    publisher's atomic ``swap_model``, never through shared mutable
    state.  ``clock`` feeds the heartbeat (injectable for deterministic
    staleness tests); :meth:`kill` / :meth:`revive` simulate replica
    death from the driving thread.
    """

    def __init__(self, config: LiveConfig, stream, *,
                 clock: Callable[[], float] = time.monotonic):
        self.config = config
        self.stream = stream
        self.d = stream.d
        R = config.replicas
        self._parts = partition_indices(
            stream.n_batch, R, config.access, config.rep_k)
        self.per = self._parts.shape[1]
        if self.per < 1:
            raise ValueError(
                f"chunk of {stream.n_batch} rows cannot feed "
                f"{R} replicas")
        if self.per % config.local_batch:
            raise ValueError(
                f"local_batch must divide the per-replica partition "
                f"{self.per} (= n_batch//replicas + rep_k), got "
                f"{config.local_batch}")
        self.heartbeat = Heartbeat(R, config.timeout_s, clock=clock)
        self.gate = MergeGate(config.merge_every, self.heartbeat)
        self.W: Array = jnp.zeros((R, self.d), jnp.float32)
        self.anchor: Array = jnp.zeros((self.d,), jnp.float32)
        self._ef: Array | None = (
            jnp.zeros((R, self.d), jnp.float32) if config.compress else None)
        self.steps = 0
        self.merges = 0
        self.merges_skipped = 0
        #: optional HealthMonitor (set by monitor.watch_live): each step
        #: reports published-snapshot staleness; one None check otherwise
        self.monitor = None
        self._merge_hooks: list[Callable[["LiveLearner"], None]] = []
        self._iter = iter(stream)
        self._epoch = self._build_epoch()

    # -- construction --------------------------------------------------------

    def _build_epoch(self):
        """The jitted ``(W, data..., alive) -> W`` replica pass.

        Dead replicas compute nothing: their rows are returned frozen
        (``where(alive)`` on the output).  Dispatch mirrors
        ``core.sgd.make_epoch_fn``'s async branches.
        """
        cfg = self.config
        task, step, lb = cfg.task, cfg.step_size, cfg.local_batch
        per, d, backend = self.per, self.d, cfg.kernel_backend
        dense = getattr(self.stream, "dense", False)

        if dense:
            if backend is not None:
                from repro.kernels.glm_sgd import glm_sgd_epoch as _kepoch

                def one(w, Xr, yr):
                    return _kepoch(task, w, Xr, yr, step=step,
                                   micro_batch=lb, backend=backend)
            else:

                def one(w, Xr, yr):
                    if lb == 1:
                        return glm.incremental_epoch(task, w, Xr, yr, step)
                    return glm.minibatch_epoch(task, w, Xr, yr, step, lb)

            @jax.jit
            def epoch(W, Xp, yp, alive):
                W_new = jax.vmap(one)(W, Xp, yp)
                return jnp.where(alive[:, None], W_new, W)

            return epoch

        if backend is not None:
            if lb == per:
                # full-partition update: glm_sparse sum gradient
                from repro.kernels.glm_sparse import ell_glm_grad as _kgrad

                def one(w, v, i, yr):
                    g = _kgrad(task, w, v, i, yr, backend=backend)
                    return w - (step / per) * g
            else:
                # mini-batch local updates: fused sparse-SGD epoch kernel
                from repro.kernels.glm_sgd_sparse import (
                    ell_sgd_epoch as _kepoch_sp,
                )

                def one(w, v, i, yr):
                    return _kepoch_sp(task, w, v, i, yr, step=step,
                                      micro_batch=lb, backend=backend)
        else:

            def one(w, v, i, yr):
                m = sparse.ELLMatrix(v, i, d)
                if lb == 1:
                    return sparse.incremental_epoch(task, w, m, yr, step)
                return sparse.minibatch_epoch(task, w, m, yr, step, lb)

        @jax.jit
        def epoch(W, vals_p, idx_p, yp, alive):
            W_new = jax.vmap(one)(W, vals_p, idx_p, yp)
            return jnp.where(alive[:, None], W_new, W)

        return epoch

    # -- liveness ------------------------------------------------------------

    def alive(self) -> np.ndarray:
        return self.gate.alive_mask()

    def kill(self, replica: int) -> None:
        """Simulate replica death: its heartbeat goes permanently stale
        (until :meth:`revive`), so it stops training and is dropped from
        merges."""
        self.heartbeat.last_seen[replica] = -np.inf
        metrics.counter("live.kills").inc()

    def revive(self, replica: int) -> None:
        """Revive a dead replica: fresh heartbeat + model re-seeded from
        the latest merged anchor (it rejoins the consensus, not its own
        stale past)."""
        self.heartbeat.beat(replica)
        self.W = self.W.at[replica].set(self.anchor)
        if self._ef is not None:
            self._ef = self._ef.at[replica].set(0.0)
        metrics.counter("live.revivals").inc()

    # -- the loop ------------------------------------------------------------

    @property
    def merged_model(self) -> Array:
        """The latest merged model ``[d]`` (zeros before the first
        merge) — what the publisher ships to the scoring engine."""
        return self.anchor

    def add_merge_hook(self, fn: Callable[["LiveLearner"], None]) -> None:
        """``fn(learner)`` runs after every completed merge (the
        publisher attaches here)."""
        self._merge_hooks.append(fn)

    def step(self) -> StreamBatch:
        """One stream step: fetch the next chunk, run one local pass on
        every *alive* replica, merge when the gate says so.  Returns the
        consumed batch."""
        batch = next(self._iter)
        alive = self.gate.alive_mask()
        with trace.span("live.step", step=self.steps, seq=batch.seq,
                        alive=int(alive.sum())):
            parts = self._parts
            yp = jnp.asarray(batch.y[parts])
            alive_j = jnp.asarray(alive)
            if getattr(self.stream, "dense", False):
                Xp = jnp.asarray(batch.X[parts])
                self.W = self._epoch(self.W, Xp, yp, alive_j)
            else:
                vals_p = jnp.asarray(batch.values[parts])
                idx_p = jnp.asarray(batch.indices[parts])
                self.W = self._epoch(self.W, vals_p, idx_p, yp, alive_j)
        # alive replicas made progress this step; dead ones stay silent
        now_alive = np.nonzero(alive)[0]
        for r in now_alive:
            self.heartbeat.beat(int(r))
        self.steps += 1
        metrics.counter("live.steps").inc()
        if self.gate.should_merge(self.steps):
            self.merge()
        if self.monitor is not None:
            self.monitor.on_learner_step(self)
        return batch

    def merge(self) -> Array | None:
        """Average the alive replicas (optionally through the int8
        error-feedback channel), redistribute, and advance the anchor.
        Returns the merged model, or None when every replica is dead
        (the merge is skipped — the stream keeps flowing)."""
        alive = self.gate.alive_mask()
        n_alive = int(alive.sum())
        if n_alive == 0:
            self.merges_skipped += 1
            metrics.counter("live.merges_skipped").inc()
            if trace.enabled():
                trace.instant("live.merge_skipped", step=self.steps)
            return None
        with trace.span("live.merge", step=self.steps, merge=self.merges,
                        alive=n_alive,
                        compressed=bool(self.config.compress)):
            alive_j = jnp.asarray(alive)
            if self.config.compress:
                merged, self._ef = _compressed_merge(
                    self.W, self.anchor, self._ef, alive_j)
            else:
                merged = _masked_mean(self.W, alive_j)
            self.W = jnp.where(alive_j[:, None],
                               jnp.broadcast_to(merged, self.W.shape),
                               self.W)
            self.anchor = merged
        self.merges += 1
        metrics.counter("live.merges").inc()
        for hook in self._merge_hooks:
            hook(self)
        return merged

    def run(self, n_steps: int) -> "LiveLearner":
        for _ in range(n_steps):
            self.step()
        return self

    def loss(self, eval_ell: sparse.ELLMatrix, y) -> float:
        """Holdout loss of the merged model (the served quantity)."""
        return float(sparse.loss(self.config.task, eval_ell,
                                 jnp.asarray(y), self.anchor))


# ---------------------------------------------------------------------------
# merge math (jitted)
# ---------------------------------------------------------------------------


@jax.jit
def _masked_mean(W: Array, alive: Array) -> Array:
    """Mean over alive rows only (dead replicas dropped from the
    consensus, paper §5.1 merge thread + MergeGate masking)."""
    mask = alive.astype(W.dtype)
    return (mask @ W) / jnp.maximum(mask.sum(), 1.0)


@jax.jit
def _compressed_merge(W: Array, anchor: Array, ef: Array,
                      alive: Array) -> tuple[Array, Array]:
    """int8 error-feedback delta exchange: each alive replica quantizes
    ``w_r - anchor`` (plus its carried residual), the dequantized deltas
    average into the new anchor, residuals persist per replica.  Dead
    replicas exchange nothing and their feedback is frozen."""

    def one(w_r, ef_r):
        delta = (w_r - anchor) + ef_r
        q, s = C.quantize_leaf(delta)
        deq = C.dequantize_leaf(q, s, delta)
        return deq, delta - deq

    deq, ef_new = jax.vmap(one)(W, ef)
    mask = alive.astype(W.dtype)
    mean_delta = (mask @ deq) / jnp.maximum(mask.sum(), 1.0)
    ef = jnp.where(alive[:, None], ef_new, ef)
    return anchor + mean_delta, ef
