"""Runtime health monitor: sliding-window SLOs over the serve+live stack.

PR 6's telemetry is *post-hoc*: sidecars at exit, ``repro.obs.report``
after the run.  Once the stack serves while it trains (``serve.glm`` +
``repro.live``), health has to be visible **while the system runs** —
the operational counterpart of the paper's three measures:

* hardware efficiency  -> windowed request p50/p99 + rps + batch fill;
* statistical efficiency -> an EWMA drift watch on the holdout-loss
  curve (divergence / plateau flags);
* time-to-convergence coupling -> snapshot staleness vs the
  publisher's guaranteed ``bound_steps`` ceiling.

:class:`HealthMonitor` maintains deterministic sliding windows over the
existing :mod:`repro.obs.metrics` primitives: a bounded fixed-edge
:class:`repro.obs.digest.QuantileDigest` per window (plus a cumulative
one), scalar accumulators for throughput/queue-depth/fill/staleness,
and the loss EWMA pair.  On every window roll the declarative
:class:`SLOSpec` predicates evaluate against the closed window's
sample; each breach increments ``slo.breach.<name>`` (plus the
``slo.breaches`` total) in the metrics registry and emits an
``slo.breach`` instant event into the trace, so breaches land on the
same stitched Perfetto timeline as the ``serve.*`` / ``live.*`` spans
that caused them.  Rolls also best-effort-flush the metrics sidecar
(:func:`repro.obs.metrics.flush`), so a chaos-killed process keeps its
partial health state — the metrics mirror of ``trace.py``'s
closed-span durability.

Hook points (all duck-typed — this module imports only obs siblings):

* ``monitor.attach_engine(engine)`` — ``GLMScoreEngine.flush`` reports
  per-batch latencies, rows, queue depth, and fill;
* ``monitor.watch_live(learner, publisher)`` — ``LiveLearner.step``
  reports per-step snapshot staleness against the publisher's bound
  captured at attach time; ``SnapshotPublisher`` reports publishes;
* ``monitor.observe_loss(v)`` — whoever evaluates holdout loss (the
  live benchmark, a serving-side canary) feeds the drift watch.

The CLI tails the sidecars a monitored run leaves behind::

    PYTHONPATH=src python -m repro.obs.monitor [DIRS...] [--check] [--json]

renders the per-process health table (windows, breach counters, last
health gauges) and with ``--check`` exits nonzero per breach — the CI
``monitor-smoke`` gate.  Everything here is sidecar-only: a monitored
benchmark run writes byte-identical ``BENCH_*.json``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import sys
import threading
import time
from pathlib import Path
from typing import Callable, Sequence

from repro.obs import metrics, trace
from repro.obs.digest import LATENCY_EDGES, QuantileDigest

#: metric-name prefixes the monitor owns inside the metrics registry
HEALTH_PREFIX = "health."
BREACH_PREFIX = "slo.breach."


# ---------------------------------------------------------------------------
# SLO predicates
# ---------------------------------------------------------------------------

_OPS: dict[str, Callable[[float, float], bool]] = {
    "<=": lambda v, t: v <= t,
    ">=": lambda v, t: v >= t,
}


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """One declarative service-level objective.

    ``metric`` names a field of the per-window health sample (see
    :meth:`HealthMonitor.roll`); ``op`` compares the observed value
    against ``threshold`` and the SLO *holds* when the comparison is
    true.  A window whose sample has no value for ``metric`` (e.g.
    staleness with no publisher attached) is skipped, not breached.
    """

    name: str
    metric: str
    op: str                     # "<=" or ">="
    threshold: float
    description: str = ""

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"SLOSpec op must be one of {sorted(_OPS)}: "
                             f"{self.op!r}")

    def holds(self, value: float) -> bool:
        return _OPS[self.op](float(value), self.threshold)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


#: serve-path defaults: generous ceilings relative to the committed
#: BENCH_serve trajectory (p99 ~2ms on CI CPU) so only real faults trip
DEFAULT_SERVE_SLOS = (
    SLOSpec("latency_p99", "p99_s", "<=", 0.5,
            "windowed request p99 stays under half a second"),
    SLOSpec("throughput", "rps", ">=", 1.0,
            "the engine keeps scoring at least one request per second"),
)

#: serve+live defaults: the serve pair plus the statistical-efficiency
#: and staleness watchdogs of the train-while-serving loop
DEFAULT_LIVE_SLOS = DEFAULT_SERVE_SLOS + (
    SLOSpec("staleness", "staleness_ratio", "<=", 1.0,
            "served snapshot never lags past the publisher's bound"),
    SLOSpec("loss_divergence", "loss_diverging", "<=", 0.0,
            "the holdout-loss EWMA watch does not flag divergence"),
)


# ---------------------------------------------------------------------------
# EWMA drift watch (statistical efficiency)
# ---------------------------------------------------------------------------


class EWMADrift:
    """Fast-vs-slow EWMA watch over the holdout-loss curve.

    Divergence: the fast average exceeds the slow one by ``tol``
    (relative) for ``patience`` consecutive observations — the loss is
    *rising* against its own recent history — or any observation is
    non-finite (the unambiguous blow-up).  Plateau: the two averages
    agree within ``plateau_eps`` (relative) for ``plateau_patience``
    observations — progress has stalled.  Plateau is an informational
    flag (a converged model plateaus legitimately); divergence is what
    the default live SLO set turns into a breach.
    """

    def __init__(self, *, alpha_fast: float = 0.5, alpha_slow: float = 0.1,
                 tol: float = 0.25, patience: int = 2,
                 plateau_eps: float = 1e-3, plateau_patience: int = 3):
        if not 0 < alpha_slow < alpha_fast <= 1:
            raise ValueError(
                f"need 0 < alpha_slow < alpha_fast <= 1: "
                f"{alpha_slow}, {alpha_fast}")
        self.alpha_fast = alpha_fast
        self.alpha_slow = alpha_slow
        self.tol = tol
        self.patience = patience
        self.plateau_eps = plateau_eps
        self.plateau_patience = plateau_patience
        self.fast: float | None = None
        self.slow: float | None = None
        self.last: float | None = None
        self.n = 0
        self._rising = 0
        self._flat = 0
        self._blown = False

    def observe(self, v: float) -> None:
        v = float(v)
        self.n += 1
        self.last = v
        if not math.isfinite(v):
            self._blown = True
            return
        if self.fast is None or self.slow is None:
            self.fast = self.slow = v
            return
        self.fast = self.alpha_fast * v + (1 - self.alpha_fast) * self.fast
        self.slow = self.alpha_slow * v + (1 - self.alpha_slow) * self.slow
        scale = max(abs(self.slow), 1e-12)
        if (self.fast - self.slow) > self.tol * scale:
            self._rising += 1
        else:
            self._rising = 0
        if abs(self.fast - self.slow) < self.plateau_eps * scale:
            self._flat += 1
        else:
            self._flat = 0

    @property
    def diverging(self) -> bool:
        return self._blown or self._rising >= self.patience

    @property
    def plateaued(self) -> bool:
        return not self._blown and self._flat >= self.plateau_patience

    @property
    def status(self) -> str:
        if self.diverging:
            return "diverging"
        if self.plateaued:
            return "plateau"
        return "ok"


# ---------------------------------------------------------------------------
# the monitor
# ---------------------------------------------------------------------------


class HealthMonitor:
    """Sliding-window health over the serve+live stack (module docstring).

    Thread-safe: ``GLMScoreEngine.flush`` may report from any number of
    consumer threads while the learner thread reports staleness.  The
    window state lives behind one lock; breach emission (metrics
    counters, trace instants, sidecar flush) happens outside it.

    ``window_s`` is the roll period checked lazily on every hook call
    (``clock`` is injectable so tests pin window boundaries without
    sleeping); :meth:`roll` forces a roll at natural boundaries (end of
    a benchmark cell).  An empty window — no scoring, no loss, no
    staleness observation — rolls as a no-op rather than evaluating
    SLOs against vacuous zeros, so idle periods never fabricate
    throughput breaches.  ``history`` keeps the last ``max_windows``
    samples (bounded, like everything else here).
    """

    def __init__(self, slos: Sequence[SLOSpec] = DEFAULT_SERVE_SLOS, *,
                 window_s: float = 1.0,
                 edges: tuple[float, ...] = LATENCY_EDGES,
                 drift: EWMADrift | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 max_windows: int = 256):
        if window_s <= 0:
            raise ValueError(f"window_s must be > 0: {window_s}")
        names = [s.name for s in slos]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO names: {names}")
        self.slos = tuple(slos)
        self.window_s = window_s
        self.edges = tuple(edges)
        self.drift = drift if drift is not None else EWMADrift()
        self.max_windows = max_windows
        self._clock = clock
        self._lock = threading.Lock()
        self.cumulative = QuantileDigest(self.edges)
        self.history: list[dict] = []
        self.windows = 0
        self.breaches: dict[str, int] = {}
        self._staleness_bound: int | None = None
        self._pub = None
        self._pending: tuple[dict, list] | None = None
        self._reset_window(self._clock())

    # -- window state (callers hold self._lock) ------------------------------

    def _reset_window(self, now: float) -> None:
        self._w_start = now
        self._w_digest = QuantileDigest(self.edges)
        self._w_scored = 0
        self._w_rejected = 0
        self._w_flushes = 0
        self._w_fill_sum = 0.0
        self._w_queue_max: int | None = None
        self._w_staleness_max: int | None = None
        self._w_loss_seen = False
        self._w_publishes = 0

    def _window_empty(self) -> bool:
        return not (self._w_flushes or self._w_rejected or self._w_loss_seen
                    or self._w_staleness_max is not None
                    or self._w_publishes)

    # -- hook points ---------------------------------------------------------

    def attach_engine(self, engine) -> "HealthMonitor":
        """Watch a ``GLMScoreEngine``: its ``flush`` reports here."""
        engine.monitor = self
        return self

    def watch_live(self, learner, publisher) -> "HealthMonitor":
        """Watch a learner/publisher pair: per-step staleness against
        the publisher's bound as captured *now* (a later fault that
        stops publishing cannot quietly relax the ceiling)."""
        with self._lock:
            self._staleness_bound = publisher.bound_steps(
                learner.config.merge_every)
            self._pub = publisher
        learner.monitor = self
        publisher.monitor = self
        return self

    def on_flush(self, *, n: int, padded: int, queue_depth: int,
                 latencies: Sequence[float]) -> None:
        """One scored micro-batch (called by the engine, any thread)."""
        with self._lock:
            self._maybe_roll_locked()
            for v in latencies:
                self._w_digest.observe(v)
                self.cumulative.observe(v)
            self._w_scored += n
            self._w_flushes += 1
            self._w_fill_sum += n / max(padded, 1)
            self._w_queue_max = queue_depth if self._w_queue_max is None \
                else max(self._w_queue_max, queue_depth)
        self._emit_pending()

    def on_reject(self) -> None:
        """One shed request (bounded-FIFO backpressure)."""
        with self._lock:
            self._maybe_roll_locked()
            self._w_rejected += 1
        self._emit_pending()

    def on_learner_step(self, learner) -> None:
        """One live-learner step: sample published-snapshot staleness."""
        pub = self._pub
        if pub is None:
            return
        lag = pub.staleness(learner)
        if lag is None:
            return
        with self._lock:
            self._maybe_roll_locked()
            self._w_staleness_max = lag if self._w_staleness_max is None \
                else max(self._w_staleness_max, lag)
        self._emit_pending()

    def on_publish(self, *, version: int, step: int) -> None:
        """One snapshot publish (called by the publisher)."""
        with self._lock:
            self._maybe_roll_locked()
            self._w_publishes += 1
        self._emit_pending()

    def observe_loss(self, v: float) -> None:
        """One holdout-loss evaluation of the served/merged model."""
        with self._lock:
            self._maybe_roll_locked()
            self.drift.observe(v)
            self._w_loss_seen = True
        self._emit_pending()

    # -- rolling -------------------------------------------------------------

    def _maybe_roll_locked(self) -> None:
        if self._clock() - self._w_start >= self.window_s:
            self._roll_locked()

    def _roll_locked(self) -> None:
        now = self._clock()
        if self._window_empty():
            self._w_start = now         # idle: slide, evaluate nothing
            return
        dur = max(now - self._w_start, 1e-9)
        d = self._w_digest
        sample: dict = {
            "window": self.windows,
            "dur_s": dur,
            "n_scored": self._w_scored,
            "rps": self._w_scored / dur if self._w_flushes else None,
            "p50_s": d.quantile(0.5),
            "p99_s": d.quantile(0.99),
            "rejected": self._w_rejected,
            "flushes": self._w_flushes,
            "batch_fill": (self._w_fill_sum / self._w_flushes
                           if self._w_flushes else None),
            "queue_depth": self._w_queue_max,
            "publishes": self._w_publishes,
            "staleness_steps": self._w_staleness_max,
            "staleness_bound": self._staleness_bound,
            "staleness_ratio": (
                self._w_staleness_max / self._staleness_bound
                if self._w_staleness_max is not None
                and self._staleness_bound else None),
            "loss": self.drift.last if self.drift.n else None,
            "loss_fast": self.drift.fast,
            "loss_slow": self.drift.slow,
            "loss_diverging": (float(self.drift.diverging)
                               if self.drift.n else None),
            "loss_plateau": (float(self.drift.plateaued)
                             if self.drift.n else None),
            "loss_status": self.drift.status if self.drift.n else None,
        }
        breached: list[tuple[SLOSpec, float]] = []
        evaluated = 0
        for slo in self.slos:
            value = sample.get(slo.metric)
            if value is None:
                continue
            evaluated += 1
            if not slo.holds(value):
                breached.append((slo, float(value)))
                self.breaches[slo.name] = self.breaches.get(slo.name, 0) + 1
        sample["breaches"] = [s.name for s, _ in breached]
        sample["evaluated"] = evaluated
        self.windows += 1
        self.history.append(sample)
        if len(self.history) > self.max_windows:
            del self.history[:len(self.history) - self.max_windows]
        self._pending = (sample, breached)
        self._reset_window(now)

    def _emit_pending(self) -> None:
        """Publish the last closed window outside the monitor lock (the
        swap is under the lock, so racing hook threads emit it once)."""
        with self._lock:
            pending, self._pending = self._pending, None
        if pending is None:
            return
        sample, breached = pending
        metrics.counter("slo.windows").inc()
        metrics.counter("slo.evaluations").inc(sample["evaluated"])
        for key in ("p50_s", "p99_s", "rps", "batch_fill", "queue_depth",
                    "staleness_steps", "staleness_bound", "loss",
                    "loss_fast", "loss_slow", "loss_diverging",
                    "loss_plateau"):
            v = sample.get(key)
            if v is not None:
                metrics.gauge(HEALTH_PREFIX + key).set(float(v))
        for slo, value in breached:
            metrics.counter("slo.breaches").inc()
            metrics.counter(BREACH_PREFIX + slo.name).inc()
            trace.instant("slo.breach", slo=slo.name, metric=slo.metric,
                          value=value, op=slo.op, threshold=slo.threshold,
                          window=sample["window"])
        metrics.flush()                  # best-effort sidecar durability

    def roll(self) -> dict | None:
        """Force-close the current window; returns its sample (None when
        the window was empty)."""
        with self._lock:
            before = self.windows
            self._roll_locked()
            sample = self.history[-1] if self.windows > before else None
        self._emit_pending()
        return sample

    # -- read-out ------------------------------------------------------------

    @property
    def total_breaches(self) -> int:
        return sum(self.breaches.values())

    def summary(self) -> dict:
        with self._lock:
            return {
                "windows": self.windows,
                "breaches": dict(sorted(self.breaches.items())),
                "total_breaches": self.total_breaches,
                "slos": [s.to_dict() for s in self.slos],
                "cumulative": {
                    "count": self.cumulative.count,
                    "p50_s": self.cumulative.quantile(0.5),
                    "p99_s": self.cumulative.quantile(0.99),
                },
                "loss_status": self.drift.status if self.drift.n else None,
                "last": self.history[-1] if self.history else None,
            }

    def table(self) -> str:
        """The health table (one row per rolled window)."""
        rows = [f"{'win':>4s} {'scored':>7s} {'rps':>9s} {'p50':>9s} "
                f"{'p99':>9s} {'fill':>5s} {'qmax':>5s} {'stale':>6s} "
                f"{'loss':>10s} {'status':10s} breaches"]
        for s in self.history:
            rows.append(
                f"{s['window']:4d} {s['n_scored']:7d} "
                f"{_fmt(s['rps'], '9.1f')} {_fmt_lat(s['p50_s'])} "
                f"{_fmt_lat(s['p99_s'])} {_fmt(s['batch_fill'], '5.2f')} "
                f"{_fmt(s['queue_depth'], '5.0f')} "
                f"{_fmt(s['staleness_steps'], '6.0f')} "
                f"{_fmt(s['loss'], '10.3f')} "
                f"{(s['loss_status'] or '-'):10s} "
                f"{','.join(s['breaches']) or '-'}")
        return "\n".join(rows)


def _fmt(v, spec: str) -> str:
    width = int(spec.split(".")[0])
    return f"{v:{spec}}" if v is not None else " " * (width - 1) + "-"


def _fmt_lat(v) -> str:
    if v is None:
        return "        -"
    return f"{v:9.3f}s" if v >= 1.0 else f"{1e3 * v:8.2f}ms"


# ---------------------------------------------------------------------------
# CLI: tail the sidecars of a monitored run
# ---------------------------------------------------------------------------


def _read_sidecars(paths: Sequence[str]) -> list[dict]:
    """Per-sidecar health views: tag, breach counters, health gauges."""
    from repro.obs import export

    out = []
    for p in export.metrics_sidecars(paths):
        try:
            snap = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError) as e:
            out.append({"path": str(p), "error": str(e)})
            continue
        counters = snap.get("counters", {})
        out.append({
            "path": str(p),
            "tag": p.stem[len("metrics-"):],
            "windows": counters.get("slo.windows", 0),
            "breaches": {k[len(BREACH_PREFIX):]: v
                         for k, v in sorted(counters.items())
                         if k.startswith(BREACH_PREFIX)},
            "health": {k[len(HEALTH_PREFIX):]: v
                       for k, v in sorted(snap.get("gauges", {}).items())
                       if k.startswith(HEALTH_PREFIX)},
        })
    return out


def _breach_instants(paths: Sequence[str]) -> int:
    """slo.breach instant events across every trace file under paths."""
    from repro.obs import export

    try:
        traces = export.collect(paths)
    except ValueError:
        return 0
    return sum(1 for t in traces for i in t.instants
               if i.get("name") == "slo.breach")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.monitor",
        description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=None,
                    help="sidecar dirs (default: $REPRO_TRACE_DIR or trace/)")
    ap.add_argument("--check", action="store_true",
                    help="exit status = total SLO breaches recorded "
                         "(nonzero also when no sidecars are found)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output for CI assertions")
    args = ap.parse_args(argv)
    paths = args.paths or [os.environ.get(trace.ENV_TRACE_DIR)
                           or trace.DEFAULT_TRACE_DIR]

    files = _read_sidecars(paths)
    total = sum(sum(f.get("breaches", {}).values()) for f in files)
    by_name: dict[str, int] = {}
    for f in files:
        for name, n in f.get("breaches", {}).items():
            by_name[name] = by_name.get(name, 0) + n
    doc = {
        "files": files,
        "windows": sum(f.get("windows", 0) for f in files),
        "breaches": dict(sorted(by_name.items())),
        "total_breaches": total,
        "trace_breach_events": _breach_instants(paths),
    }

    if args.json:
        print(json.dumps(doc, indent=1, sort_keys=True))
    else:
        if not files:
            print(f"no metrics sidecars under {paths} (run with "
                  f"REPRO_METRICS=1 or REPRO_TRACE=1 and a HealthMonitor "
                  f"attached; see docs/OBSERVABILITY.md)", file=sys.stderr)
        for f in files:
            if "error" in f:
                print(f"{f['path']}: unreadable ({f['error']})",
                      file=sys.stderr)
                continue
            h = f["health"]
            print(f"{f['tag']:16s} windows={f['windows']:<4d} "
                  f"p50={_fmt_lat(h.get('p50_s')).strip():>9s} "
                  f"p99={_fmt_lat(h.get('p99_s')).strip():>9s} "
                  f"rps={_fmt(h.get('rps'), '9.1f').strip():>9s} "
                  f"stale={_fmt(h.get('staleness_steps'), '4.0f').strip():>4s}"
                  f" breaches={sum(f['breaches'].values())}")
            for name, n in f["breaches"].items():
                print(f"  BREACH {name:24s} x{n}")
        print(f"{len(files)} sidecar(s), {doc['windows']} window(s), "
              f"{total} breach(es), "
              f"{doc['trace_breach_events']} slo.breach trace event(s)")

    if args.check:
        return total if files else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
