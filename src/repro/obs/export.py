"""Trace-file loading, timeline stitching, Chrome trace-event export.

A traced run leaves one JSONL file per process in the trace dir —
``trace-main-<pid>.jsonl`` for the driver, ``trace-shard<W>a<A>-<pid>``
per sweep-worker attempt.  This module merges them into one timeline:

* each file's spans are aligned onto the wall clock using the meta
  line's ``(t0_unix_ns, t0_perf_ns)`` anchor pair (per-process
  monotonic clocks have arbitrary origins; the anchors calibrate them);
* each file becomes one Chrome "process" row, named by its shard tag,
  so a ``--workers N`` sweep renders as the driver plus N worker lanes
  in a single Perfetto view — dead-worker requeues included, as extra
  ``shard<W>a<A+1>`` lanes;
* span events use the Chrome trace-event ``"ph": "X"`` (complete)
  format with microsecond timestamps, loadable at
  https://ui.perfetto.dev or ``chrome://tracing``.

Schema validation happens on *read*: a trace file whose meta line is
missing or stamped with a schema newer than :data:`trace.TRACE_SCHEMA`
raises instead of silently misparsing.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Iterable, Sequence

from repro.obs import trace as trace_mod

TRACE_GLOB = "trace-*.jsonl"
METRICS_GLOB = "metrics-*.json"


@dataclasses.dataclass
class FileTrace:
    """One process's parsed trace file."""

    path: Path
    meta: dict
    spans: list[dict]
    instants: list[dict]

    @property
    def tag(self) -> str:
        return self.meta.get("tag", "?")

    @property
    def pid(self) -> int:
        return int(self.meta.get("pid", 0))

    def unix_ns(self, ts_perf: int) -> int:
        """Align one perf_counter_ns stamp onto the wall clock."""
        return (self.meta["t0_unix_ns"]
                + (int(ts_perf) - self.meta["t0_perf_ns"]))


def read_trace(path: str | Path) -> FileTrace:
    """Parse + validate one trace file (raises on schema drift)."""
    path = Path(path)
    meta = None
    spans: list[dict] = []
    instants: list[dict] = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i}: not JSON ({e})") from None
            kind = rec.get("kind")
            if kind == "meta":
                schema = rec.get("schema")
                if not isinstance(schema, int) \
                        or schema > trace_mod.TRACE_SCHEMA:
                    raise ValueError(
                        f"{path}:{i}: trace schema {schema!r} is newer than "
                        f"this reader ({trace_mod.TRACE_SCHEMA}); upgrade "
                        f"repro.obs or re-record the trace")
                meta = rec
            elif kind == "span":
                spans.append(rec)
            elif kind == "instant":
                instants.append(rec)
            else:
                raise ValueError(f"{path}:{i}: unknown record kind {kind!r}")
    if meta is None:
        raise ValueError(f"{path}: no meta line (truncated or not a "
                         f"repro.obs trace file)")
    return FileTrace(path=path, meta=meta, spans=spans, instants=instants)


def collect(paths: Sequence[str | Path]) -> list[FileTrace]:
    """Load every trace file named by ``paths`` (dirs are globbed)."""
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.glob(TRACE_GLOB)))
        elif p.exists():
            files.append(p)
    traces = [read_trace(f) for f in files]
    traces.sort(key=lambda t: (t.tag != trace_mod.DEFAULT_TAG, t.tag, t.pid))
    return traces


def metrics_sidecars(paths: Sequence[str | Path]) -> list[Path]:
    out: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(sorted(p.glob(METRICS_GLOB)))
    return out


# ---------------------------------------------------------------------------
# Chrome trace-event export
# ---------------------------------------------------------------------------


def chrome_events(traces: Sequence[FileTrace]) -> list[dict]:
    """Merge per-process traces into one chrome trace-event list.

    Each file gets a stable small synthetic pid (its rank in the sorted
    file list) so two processes that happened to share an OS pid — or
    the same process traced twice — never interleave; the real pid and
    shard tag go into the process_name metadata row.
    """
    anchors = [t.meta["t0_unix_ns"] for t in traces if t.spans or t.instants]
    base_ns = min((t.unix_ns(min(s["ts"] for s in t.spans + t.instants))
                   for t in traces if t.spans or t.instants),
                  default=min(anchors, default=0))
    events: list[dict] = []
    for rank, t in enumerate(traces, start=1):
        tid_map: dict[int, int] = {}
        events.append({
            "ph": "M", "name": "process_name", "pid": rank, "tid": 0,
            "args": {"name": f"{t.tag} (pid {t.pid})"},
        })
        events.append({
            "ph": "M", "name": "process_sort_index", "pid": rank, "tid": 0,
            "args": {"sort_index": rank},
        })
        for rec in sorted(t.spans + t.instants, key=lambda r: r["ts"]):
            tid = tid_map.setdefault(rec.get("tid", 0), len(tid_map) + 1)
            ev = {
                "name": rec["name"],
                "cat": rec["name"].split(".", 1)[0],
                "ph": "X" if rec["kind"] == "span" else "i",
                "ts": (t.unix_ns(rec["ts"]) - base_ns) / 1000.0,
                "pid": rank,
                "tid": tid,
                "args": {**rec.get("args", {}), "depth": rec.get("depth", 0)},
            }
            if rec["kind"] == "span":
                ev["dur"] = rec["dur"] / 1000.0
            else:
                ev["s"] = "t"
            events.append(ev)
    return events


def to_chrome(traces: Sequence[FileTrace]) -> dict:
    return {"traceEvents": chrome_events(traces), "displayTimeUnit": "ms"}


def write_chrome(traces: Sequence[FileTrace], out: str | Path) -> Path:
    out = Path(out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(to_chrome(traces)) + "\n")
    return out


def validate_chrome(doc: dict) -> list[str]:
    """Shape-check an exported document against the trace-event format."""
    bad: list[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents is not a list"]
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph not in ("X", "i", "M"):
            bad.append(f"event {i}: unsupported ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            bad.append(f"event {i}: missing name")
        if not isinstance(ev.get("pid"), int):
            bad.append(f"event {i}: missing pid")
        if ph == "M":
            continue
        if not isinstance(ev.get("tid"), int):
            bad.append(f"event {i}: missing tid")
        if not isinstance(ev.get("ts"), (int, float)) or ev["ts"] < 0:
            bad.append(f"event {i}: bad ts {ev.get('ts')!r}")
        if ph == "X" and (not isinstance(ev.get("dur"), (int, float))
                          or ev["dur"] < 0):
            bad.append(f"event {i}: bad dur {ev.get('dur')!r}")
    return bad


# ---------------------------------------------------------------------------
# Per-phase breakdown (self vs children)
# ---------------------------------------------------------------------------


def breakdown(traces: Iterable[FileTrace]) -> dict[str, dict]:
    """Aggregate spans by name: count, total and *self* wall time.

    Self time is a span's duration minus its direct children's — the
    classic profile decomposition, computed per (process, thread) via
    interval containment (spans within one thread nest properly).
    Returns ``{name: {"count", "total_s", "self_s"}}``.
    """
    agg: dict[str, dict] = {}
    by_thread: dict[tuple, list[dict]] = {}
    for t in traces:
        for s in t.spans:
            by_thread.setdefault((id(t), s.get("tid", 0)), []).append(s)
    for spans in by_thread.values():
        spans.sort(key=lambda s: (s["ts"], -s["dur"]))
        stack: list[dict] = []
        for s in spans:
            while stack and s["ts"] >= stack[-1]["ts"] + stack[-1]["dur"]:
                stack.pop()
            s.setdefault("_child_ns", 0)
            if stack:
                stack[-1]["_child_ns"] = (stack[-1].get("_child_ns", 0)
                                          + s["dur"])
            stack.append(s)
        for s in spans:
            a = agg.setdefault(s["name"],
                               {"count": 0, "total_s": 0.0, "self_s": 0.0})
            a["count"] += 1
            a["total_s"] += s["dur"] / 1e9
            a["self_s"] += (s["dur"] - s.get("_child_ns", 0)) / 1e9
    return agg


def layers(traces: Iterable[FileTrace]) -> tuple[str, ...]:
    """The distinct top-level span categories present (``kernel``,
    ``engine``, ``runner``, ``sweep``, ...)."""
    return tuple(sorted({s["name"].split(".", 1)[0]
                         for t in traces for s in t.spans}))
