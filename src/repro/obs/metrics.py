"""Process-local metrics registry: counters, gauges, histograms.

Complement to :mod:`repro.obs.trace`: spans answer *where did the time
go*, metrics answer *how often did things happen* — cache hit ratios,
backend-fallback counts, queue depths.  The registry is always live
(an increment is a dict lookup + add under a lock — cheap enough for
cache-lookup call sites), but it is only ever *persisted* as a sidecar
file next to the trace files, and **never** into the deterministic
``BENCH_*.json`` snapshots: metric values are run-dependent by nature.

Histograms use **fixed bucket edges chosen at creation** (default: the
decades from 1µs to 100s, a wall-clock scale) so two runs — or two
sweep workers — produce structurally identical, mergeable snapshots;
edges are part of the snapshot and re-registration with different edges
is an error rather than a silent reshape.

Sidecar: when tracing is enabled at process exit, the snapshot is
written to ``<trace dir>/metrics-<tag>-<pid>.json`` (schema-stamped).
``python -m repro.obs.report`` sums counters across sidecars and
``--check`` validates their schema.
"""
from __future__ import annotations

import atexit
import bisect
import json
import os
import threading
from pathlib import Path

from repro.obs import trace

#: bump when the sidecar layout changes incompatibly
METRICS_SCHEMA = 1

#: default histogram edges: decades of seconds from 1µs to 100s
DEFAULT_EDGES = tuple(10.0 ** e for e in range(-6, 3))


class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with _LOCK:
            self.value += n


class Gauge:
    """Last-write-wins scalar (queue depth, stack size, ...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        with _LOCK:
            self.value = float(v)


class Histogram:
    """Fixed-edge histogram; bucket ``i`` counts values <= ``edges[i]``
    (the last bucket is the +inf overflow)."""

    __slots__ = ("name", "edges", "counts", "count", "sum", "min", "max")

    def __init__(self, name: str, edges: tuple[float, ...] = DEFAULT_EDGES):
        if list(edges) != sorted(edges) or len(edges) < 1:
            raise ValueError(f"histogram edges must be sorted, non-empty: "
                             f"{edges!r}")
        self.name = name
        self.edges = tuple(float(e) for e in edges)
        self.counts = [0] * (len(edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None

    def observe(self, v: float) -> None:
        v = float(v)
        with _LOCK:
            self.counts[bisect.bisect_left(self.edges, v)] += 1
            self.count += 1
            self.sum += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)


_LOCK = threading.Lock()
_REGISTRY: dict[str, Counter | Gauge | Histogram] = {}


def _get(name: str, cls, *args):
    with _LOCK:
        m = _REGISTRY.get(name)
        if m is None:
            m = cls(name, *args)
            _REGISTRY[name] = m
    if not isinstance(m, cls):
        raise TypeError(f"metric {name!r} already registered as "
                        f"{type(m).__name__}, not {cls.__name__}")
    return m


def counter(name: str) -> Counter:
    return _get(name, Counter)


def gauge(name: str) -> Gauge:
    return _get(name, Gauge)


def histogram(name: str, edges: tuple[float, ...] = DEFAULT_EDGES) -> Histogram:
    h = _get(name, Histogram, edges)
    if h.edges != tuple(float(e) for e in edges):
        raise ValueError(f"histogram {name!r} already registered with edges "
                         f"{h.edges}, not {edges}")
    return h


def reset() -> None:
    """Drop every registered metric (tests)."""
    with _LOCK:
        _REGISTRY.clear()


def snapshot() -> dict:
    """Deterministically ordered view of every registered metric."""
    with _LOCK:
        items = sorted(_REGISTRY.items())
    out: dict = {"schema": METRICS_SCHEMA, "counters": {}, "gauges": {},
                 "histograms": {}}
    for name, m in items:
        if isinstance(m, Counter):
            out["counters"][name] = m.value
        elif isinstance(m, Gauge):
            out["gauges"][name] = m.value
        else:
            out["histograms"][name] = {
                "edges": list(m.edges), "counts": list(m.counts),
                "count": m.count, "sum": m.sum, "min": m.min, "max": m.max,
            }
    return out


def write_sidecar(path: str | Path | None = None) -> Path | None:
    """Write the snapshot sidecar (explicit path, or the trace dir).

    With no path and tracing disabled this is a no-op returning None —
    metrics piggyback on the tracing opt-in.
    """
    if path is None:
        root = trace.current_dir()
        if root is None:
            return None
        tag = os.environ.get(trace.ENV_TRACE_TAG) or trace.DEFAULT_TAG
        path = root / f"metrics-{tag}-{os.getpid()}.json"
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(snapshot(), sort_keys=True, indent=1) + "\n")
    return path


@atexit.register
def _flush_at_exit() -> None:  # pragma: no cover - exercised via subprocesses
    try:
        if _REGISTRY:
            write_sidecar()
    except Exception:
        pass  # never let telemetry turn a clean exit into a traceback
