"""Process-local metrics registry: counters, gauges, histograms.

Complement to :mod:`repro.obs.trace`: spans answer *where did the time
go*, metrics answer *how often did things happen* — cache hit ratios,
backend-fallback counts, queue depths.  The registry is always live
(an increment is a dict lookup + add under a lock — cheap enough for
cache-lookup call sites), but it is only ever *persisted* as a sidecar
file next to the trace files, and **never** into the deterministic
``BENCH_*.json`` snapshots: metric values are run-dependent by nature.

Histograms use **fixed bucket edges chosen at creation** (default: the
decades from 1µs to 100s, a wall-clock scale) so two runs — or two
sweep workers — produce structurally identical, mergeable snapshots;
edges are part of the snapshot and re-registration with different edges
is an error rather than a silent reshape.

Sidecar: when metrics persistence is enabled (``REPRO_METRICS=1`` on
its own — monitor mode without span-tracing overhead — or implied by
``REPRO_TRACE=1``), the snapshot is written to
``<trace dir>/metrics-<tag>-<pid>.json`` (schema-stamped): once at
process exit, and *best-effort during the run* via :func:`flush` — a
rate-limited atomic rewrite (tmp + rename), so the file is always a
complete, readable snapshot and a SIGKILLed worker or dead replica
keeps its partial metrics, mirroring ``trace.py``'s closed-span
durability.  Call sites that mark durability points (the sweep worker
after every stack group, the health monitor on every window roll) call
``flush()``; everyone else relies on the ``atexit`` write.
``python -m repro.obs.report`` sums counters across sidecars and
``--check`` validates their schema.
"""
from __future__ import annotations

import atexit
import bisect
import json
import os
import threading
import time
from pathlib import Path

from repro.obs import trace

#: bump when the sidecar layout changes incompatibly
METRICS_SCHEMA = 1

ENV_METRICS = "REPRO_METRICS"

#: floor between two best-effort flushes (seconds); keeps hot call
#: sites from turning the sidecar into an I/O hot loop
FLUSH_MIN_INTERVAL_S = 0.25

#: default histogram edges: decades of seconds from 1µs to 100s
DEFAULT_EDGES = tuple(10.0 ** e for e in range(-6, 3))


class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with _LOCK:
            self.value += n


class Gauge:
    """Last-write-wins scalar (queue depth, stack size, ...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        with _LOCK:
            self.value = float(v)


class Histogram:
    """Fixed-edge histogram; bucket ``i`` counts values <= ``edges[i]``
    (the last bucket is the +inf overflow)."""

    __slots__ = ("name", "edges", "counts", "count", "sum", "min", "max")

    def __init__(self, name: str, edges: tuple[float, ...] = DEFAULT_EDGES):
        if list(edges) != sorted(edges) or len(edges) < 1:
            raise ValueError(f"histogram edges must be sorted, non-empty: "
                             f"{edges!r}")
        self.name = name
        self.edges = tuple(float(e) for e in edges)
        self.counts = [0] * (len(edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None

    def observe(self, v: float) -> None:
        v = float(v)
        with _LOCK:
            self.counts[bisect.bisect_left(self.edges, v)] += 1
            self.count += 1
            self.sum += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)


_LOCK = threading.Lock()
_REGISTRY: dict[str, Counter | Gauge | Histogram] = {}


def _get(name: str, cls, *args):
    with _LOCK:
        m = _REGISTRY.get(name)
        if m is None:
            m = cls(name, *args)
            _REGISTRY[name] = m
    if not isinstance(m, cls):
        raise TypeError(f"metric {name!r} already registered as "
                        f"{type(m).__name__}, not {cls.__name__}")
    return m


def counter(name: str) -> Counter:
    return _get(name, Counter)


def gauge(name: str) -> Gauge:
    return _get(name, Gauge)


def histogram(name: str, edges: tuple[float, ...] = DEFAULT_EDGES) -> Histogram:
    h = _get(name, Histogram, edges)
    if h.edges != tuple(float(e) for e in edges):
        raise ValueError(f"histogram {name!r} already registered with edges "
                         f"{h.edges}, not {edges}")
    return h


def reset() -> None:
    """Drop every registered metric (tests)."""
    with _LOCK:
        _REGISTRY.clear()


def snapshot() -> dict:
    """Deterministically ordered view of every registered metric."""
    with _LOCK:
        items = sorted(_REGISTRY.items())
    out: dict = {"schema": METRICS_SCHEMA, "counters": {}, "gauges": {},
                 "histograms": {}}
    for name, m in items:
        if isinstance(m, Counter):
            out["counters"][name] = m.value
        elif isinstance(m, Gauge):
            out["gauges"][name] = m.value
        else:
            out["histograms"][name] = {
                "edges": list(m.edges), "counts": list(m.counts),
                "count": m.count, "sum": m.sum, "min": m.min, "max": m.max,
            }
    return out


def enabled() -> bool:
    """Whether the sidecar is persisted: ``REPRO_METRICS=1`` alone, or
    implied by tracing.  The registry itself is always live; with both
    off the only cost anywhere is this env lookup on flush paths (the
    increment fast path never checks)."""
    return os.environ.get(ENV_METRICS) == "1" or trace.enabled()


def sidecar_path() -> Path | None:
    """This process's sidecar file (None when persistence is disabled).

    Tracing pins the directory; metrics-only mode reads the same
    ``REPRO_TRACE_DIR`` convention so both signals land side by side.
    """
    root = trace.current_dir()
    if root is None:
        if not enabled():
            return None
        root = Path(os.environ.get(trace.ENV_TRACE_DIR)
                    or trace.DEFAULT_TRACE_DIR)
    tag = os.environ.get(trace.ENV_TRACE_TAG) or trace.DEFAULT_TAG
    return root / f"metrics-{tag}-{os.getpid()}.json"


def write_sidecar(path: str | Path | None = None) -> Path | None:
    """Write the snapshot sidecar (explicit path, or the default).

    With no path and persistence disabled this is a no-op returning
    None.  The write is atomic (tmp + rename): a reader — or a SIGKILL
    — never sees a half-written file.
    """
    if path is None:
        path = sidecar_path()
        if path is None:
            return None
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(path.name + f".tmp{os.getpid()}")
    tmp.write_text(json.dumps(snapshot(), sort_keys=True, indent=1) + "\n")
    os.replace(tmp, path)
    return path


_last_flush = 0.0


def flush(min_interval_s: float = FLUSH_MIN_INTERVAL_S) -> Path | None:
    """Best-effort mid-run sidecar write, rate-limited and never raising.

    Returns the path written, or None when persistence is disabled, the
    floor hasn't elapsed, or the write failed (telemetry must never
    take the instrumented path down).
    """
    global _last_flush
    if not enabled():
        return None
    now = time.monotonic()
    if min_interval_s > 0 and now - _last_flush < min_interval_s:
        return None
    try:
        p = write_sidecar()
    except Exception:
        return None
    if p is not None:
        _last_flush = now
    return p


@atexit.register
def _flush_at_exit() -> None:  # pragma: no cover - exercised via subprocesses
    try:
        if _REGISTRY:
            write_sidecar()
    except Exception:
        pass  # never let telemetry turn a clean exit into a traceback
