"""End-to-end telemetry: span tracing, metrics, Perfetto export.

The paper decomposes time-to-convergence into hardware efficiency and
statistical efficiency; this package decomposes *where the wall clock
goes* the same way — per phase, per process, per worker — so a claim
like "the async sweep is merge-bound" is a measurement, not a guess.

Three pieces (each importable on its own, stdlib-only):

* :mod:`repro.obs.trace`   — structured span tracer.  Disabled it costs
  one ``None`` check per span; ``REPRO_TRACE=1`` turns every process
  into a JSONL trace-file writer (``$REPRO_TRACE_DIR``, default
  ``trace/``).  Sweep workers inherit the env and write their own
  files, tagged by shard id.
* :mod:`repro.obs.metrics` — process-local counters / gauges /
  histograms with fixed deterministic bucket edges, snapshotted to a
  sidecar next to the trace files — never into ``BENCH_*.json``.
  ``REPRO_METRICS=1`` persists the sidecar without span tracing;
  ``metrics.flush()`` is the rate-limited mid-run durability write.
* :mod:`repro.obs.report`  — ``python -m repro.obs.report``: merges one
  or many trace files into a per-phase time breakdown (self vs
  children) and a Chrome-trace / Perfetto JSON (``--perfetto out.json``)
  one can load at https://ui.perfetto.dev; ``--check`` validates the
  emitted files against the trace-event shape; ``--json`` emits the
  report as data for CI assertions.
* :mod:`repro.obs.digest` / :mod:`repro.obs.monitor` — the runtime
  health layer: a bounded fixed-edge streaming quantile sketch, and a
  :class:`~repro.obs.monitor.HealthMonitor` that folds serve/live
  telemetry into sliding windows, evaluates declarative ``SLOSpec``
  predicates on every roll, and emits ``slo.breach`` instants +
  ``slo.*`` counters.  ``python -m repro.obs.monitor --check`` is the
  health gate (exit status = breach count).

Instrumented layers: kernel dispatch (``kernel.*``), the SGD engines
(``engine.*``), trial execution (``runner.*`` / ``study.*``), dataset
ingestion (``ingest.*``), the sweep executor and its workers
(``sweep.*``), and the benchmark driver (``bench.*``).  See
docs/OBSERVABILITY.md for the span schema and a walkthrough.
"""
from repro.obs import digest, export, metrics, trace  # noqa: F401
