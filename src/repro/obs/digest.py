"""Bounded streaming quantile digest over fixed bucket edges.

The health monitor needs request-latency p50/p99 *while the system
runs*, over an unbounded observation stream, without unbounded memory
and without sorting anything on the hot path.  The classic answer is a
sketch (t-digest, DDSketch); the repo's answer follows the metrics
registry's histogram discipline instead: **fixed bucket edges chosen at
creation**, so the digest is

* bounded — one int per bucket, forever, regardless of stream length;
* deterministic — the same observation multiset always yields the same
  counts, the same interpolated quantiles, the same snapshot bytes
  (there is no randomized compression step to make two runs disagree);
* mergeable — two digests with identical edges add bucket-wise, the
  same property that lets sweep-worker histogram sidecars sum.

Quantiles are read back by walking the cumulative counts to the bucket
containing the target rank and interpolating linearly inside it (the
DDSketch read-out, with the first/last bucket clamped to the observed
min/max so the estimate never leaves the data's range).  Accuracy is
the bucket's relative width — the default latency edges place 4 buckets
per decade from 1µs to 100s, i.e. ~29% worst-case relative error, which
is the right trade for SLO predicates ("p99 under 500ms") that compare
against thresholds orders of magnitude apart.
"""
from __future__ import annotations

import bisect

#: default latency edges: 4 log-spaced buckets per decade, 1µs .. 100s
LATENCY_EDGES = tuple(10.0 ** (e / 4.0) for e in range(-24, 9))


class QuantileDigest:
    """Fixed-edge bucket sketch with interpolated quantile read-out.

    Bucket ``i`` counts values ``v <= edges[i]`` (``bisect_left``
    placement, matching :class:`repro.obs.metrics.Histogram`); the last
    bucket is the +inf overflow.  ``merge`` requires identical edges.
    """

    __slots__ = ("edges", "counts", "count", "sum", "min", "max")

    def __init__(self, edges: tuple[float, ...] = LATENCY_EDGES):
        edges = tuple(float(e) for e in edges)
        if list(edges) != sorted(edges) or len(edges) < 1 \
                or len(set(edges)) != len(edges):
            raise ValueError(
                f"digest edges must be sorted, unique, non-empty: {edges!r}")
        self.edges = edges
        self.counts = [0] * (len(edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min: float | None = None
        self.max: float | None = None

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    @property
    def mean(self) -> float | None:
        return self.sum / self.count if self.count else None

    def quantile(self, q: float) -> float | None:
        """Interpolated q-quantile estimate (None while empty).

        Deterministic and monotone in ``q``; exact for q=0 / q=1 (the
        observed min/max), bucket-interpolated in between.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile q must be in [0, 1]: {q}")
        if self.count == 0:
            return None
        assert self.min is not None and self.max is not None
        rank = q * (self.count - 1)         # 0-based fractional rank
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c > rank:
                lo = self.min if i == 0 else self.edges[i - 1]
                hi = self.max if i == len(self.edges) else self.edges[i]
                frac = min(1.0, (rank - cum + 1.0) / c)
                v = lo + (hi - lo) * frac
                return min(max(v, self.min), self.max)
            cum += c
        return self.max                     # rank beyond last bucket

    def merge(self, other: "QuantileDigest") -> "QuantileDigest":
        """Add ``other``'s buckets into this digest (identical edges)."""
        if other.edges != self.edges:
            raise ValueError(
                f"cannot merge digests with different edges: "
                f"{len(self.edges)} vs {len(other.edges)} edge(s)")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum += other.sum
        if other.min is not None:
            self.min = other.min if self.min is None \
                else min(self.min, other.min)
        if other.max is not None:
            self.max = other.max if self.max is None \
                else max(self.max, other.max)
        return self

    # -- persistence (metrics-sidecar friendly) ------------------------------

    def snapshot(self) -> dict:
        return {"edges": list(self.edges), "counts": list(self.counts),
                "count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max}

    @classmethod
    def from_snapshot(cls, snap: dict) -> "QuantileDigest":
        d = cls(tuple(snap["edges"]))
        counts = list(snap["counts"])
        if len(counts) != len(d.counts):
            raise ValueError(
                f"digest snapshot has {len(counts)} buckets for "
                f"{len(d.edges)} edges")
        d.counts = [int(c) for c in counts]
        d.count = int(snap["count"])
        d.sum = float(snap["sum"])
        d.min = None if snap["min"] is None else float(snap["min"])
        d.max = None if snap["max"] is None else float(snap["max"])
        return d
