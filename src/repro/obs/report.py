"""Trace report CLI: per-phase breakdown, Perfetto export, validation.

    PYTHONPATH=src python -m repro.obs.report [PATHS...]
        [--perfetto OUT.json] [--check] [--top N]

``PATHS`` are trace files or directories holding ``trace-*.jsonl``
(default: ``$REPRO_TRACE_DIR`` or ``trace/``).  All files merge into one
timeline — the driver plus every sweep-worker shard attempt.

* default output: a per-phase table (count, total, self, mean) sorted
  by total time, plus the layer list and per-worker file inventory;
* ``--json``: the same content as one machine-readable JSON document
  on stdout (files, layers, breakdown, summed counters, instant-event
  counts) — what CI smoke jobs assert on instead of grepping tables;
* ``--perfetto OUT.json`` additionally writes the merged Chrome
  trace-event JSON (load at https://ui.perfetto.dev);
* ``--check`` validates everything instead of (just) reporting: trace
  schema on read, span fields, the exported trace-event shape, and
  metrics-sidecar schemas.  Exit status is the number of problems —
  CI's smoke gate.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

from repro.obs import export, metrics, trace


def _default_paths() -> list[str]:
    return [os.environ.get(trace.ENV_TRACE_DIR) or trace.DEFAULT_TRACE_DIR]


def _fmt_s(s: float) -> str:
    if s >= 1.0:
        return f"{s:8.3f}s "
    return f"{1e3 * s:8.2f}ms"


def _print_breakdown(traces, top: int) -> None:
    agg = export.breakdown(traces)
    if not agg:
        print("no spans recorded")
        return
    rows = sorted(agg.items(), key=lambda kv: -kv[1]["total_s"])
    name_w = max(len(n) for n, _ in rows[:top])
    print(f"{'span':{name_w}s} {'count':>7s} {'total':>10s} "
          f"{'self':>10s} {'mean':>10s}")
    for name, a in rows[:top]:
        print(f"{name:{name_w}s} {a['count']:7d} {_fmt_s(a['total_s'])} "
              f"{_fmt_s(a['self_s'])} {_fmt_s(a['total_s'] / a['count'])}")
    if len(rows) > top:
        print(f"... {len(rows) - top} more span name(s); --top to widen")


def _print_inventory(traces) -> None:
    print(f"\n{len(traces)} trace file(s); "
          f"layers: {', '.join(export.layers(traces)) or '(none)'}")
    for t in traces:
        span_s = sum(s["dur"] for s in t.spans) / 1e9
        print(f"  {t.tag:12s} pid {t.pid:<8d} {len(t.spans):5d} spans "
              f"{_fmt_s(span_s)}  {t.path}")


def _check_metrics(paths) -> list[str]:
    bad = []
    for p in export.metrics_sidecars(paths):
        try:
            snap = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError) as e:
            bad.append(f"{p}: unreadable metrics sidecar ({e})")
            continue
        schema = snap.get("schema")
        if not isinstance(schema, int) or schema > metrics.METRICS_SCHEMA:
            bad.append(f"{p}: metrics schema {schema!r} newer than reader "
                       f"({metrics.METRICS_SCHEMA})")
    return bad


def _sum_counters(paths) -> tuple[dict[str, int], list[Path]]:
    sums: dict[str, int] = {}
    files = export.metrics_sidecars(paths)
    for p in files:
        try:
            snap = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError):
            continue
        for name, v in snap.get("counters", {}).items():
            sums[name] = sums.get(name, 0) + v
    return sums, files


def _print_metrics(paths) -> None:
    sums, files = _sum_counters(paths)
    if sums:
        print(f"\ncounters (summed over {len(files)} sidecar(s)):")
        for name in sorted(sums):
            print(f"  {name:48s} {sums[name]:10d}")


def _json_doc(traces, paths) -> dict:
    """Machine-readable report: everything the tables print, as data."""
    counters, files = _sum_counters(paths)
    instants: dict[str, int] = {}
    for t in traces:
        for rec in t.instants:
            name = rec.get("name", "?")
            instants[name] = instants.get(name, 0) + 1
    return {
        "files": [{"tag": t.tag, "pid": t.pid, "spans": len(t.spans),
                   "instants": len(t.instants),
                   "span_s": sum(s["dur"] for s in t.spans) / 1e9,
                   "path": str(t.path)} for t in traces],
        "layers": list(export.layers(traces)),
        "spans": {name: a for name, a in sorted(export.breakdown(traces)
                                                .items())},
        "instants": dict(sorted(instants.items())),
        "counters": dict(sorted(counters.items())),
        "metrics_files": [str(p) for p in files],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=None,
                    help="trace files or dirs (default: $REPRO_TRACE_DIR "
                         "or trace/)")
    ap.add_argument("--perfetto", metavar="OUT.json", default=None,
                    help="write the merged Chrome trace-event JSON here")
    ap.add_argument("--check", action="store_true",
                    help="validate traces + export + metrics sidecars; "
                         "exit status = number of problems")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as one JSON document on stdout "
                         "(files, layers, spans, instants, counters)")
    ap.add_argument("--top", type=int, default=24,
                    help="max span names in the breakdown table")
    args = ap.parse_args(argv)
    paths = args.paths or _default_paths()

    try:
        traces = export.collect(paths)
    except ValueError as e:
        print(f"invalid trace: {e}", file=sys.stderr)
        return 1
    if not traces:
        print(f"no trace files under {paths} (run with REPRO_TRACE=1 to "
              f"record; see docs/OBSERVABILITY.md)", file=sys.stderr)
        return 1

    doc = export.to_chrome(traces)
    if args.perfetto:
        out = export.write_chrome(traces, args.perfetto)
        print(f"wrote {out} ({len(doc['traceEvents'])} events) — "
              f"load at https://ui.perfetto.dev")

    if args.check:
        problems = export.validate_chrome(doc) + _check_metrics(paths)
        n_spans = sum(len(t.spans) for t in traces)
        if problems:
            for p in problems:
                print(f"PROBLEM: {p}", file=sys.stderr)
            return len(problems)
        print(f"OK: {n_spans} spans across {len(traces)} file(s), "
              f"layers: {', '.join(export.layers(traces))}")
        return 0

    if args.json:
        json.dump(_json_doc(traces, paths), sys.stdout, sort_keys=True,
                  indent=1)
        print()
        return 0

    _print_breakdown(traces, args.top)
    _print_inventory(traces)
    _print_metrics(paths)
    return 0


if __name__ == "__main__":
    sys.exit(main())
