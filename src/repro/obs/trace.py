"""Structured span tracer — zero overhead unless ``REPRO_TRACE=1``.

Usage::

    from repro.obs import trace

    with trace.span("runner.trial", key=t.key):
        ...

    @trace.span("study.tune")
    def tune(...): ...

Disabled (the default), ``trace.span`` returns a shared no-op context
manager after a single ``None`` check — no allocation beyond the kwargs
dict at the call site, no I/O, no interaction with jit tracing (spans
are pure host-side bookkeeping, so a jitted function lowers identically
with tracing on or off; tests assert this).

Enabled (``REPRO_TRACE=1``), every process appends one JSON line per
closed span to its own file ``$REPRO_TRACE_DIR/trace-<tag>-<pid>.jsonl``
(dir default: ``trace/``).  ``tag`` comes from ``$REPRO_TRACE_TAG``
("main" when unset); the sweep executor sets it per worker subprocess
(``shard<W>a<A>``) so a multi-worker run yields one file per shard
attempt and the report CLI can stitch them into a single timeline.

File format (``TRACE_SCHEMA``):

* line 1 — meta: ``{"kind": "meta", "schema": 1, "pid", "tag",
  "t0_unix_ns", "t0_perf_ns", "argv"}``.  The two anchors let the
  exporter align per-process monotonic clocks onto one wall-clock
  timeline (``unix_ns = t0_unix_ns + (ts - t0_perf_ns)``).
* span lines — ``{"kind": "span", "name", "ts", "dur" (both
  perf_counter_ns), "pid", "tid", "depth", "args"}``.  ``depth`` is the
  thread-local nesting level at entry; spans are written at *exit*, so
  a crashed process keeps every span that finished before the crash.
* instant lines — ``{"kind": "instant", ...}`` with ``dur`` 0.

``REPRO_TRACE_XPROF=<pattern>`` additionally wraps the first trial whose
label matches the pattern (``1`` matches any) in a ``jax.profiler``
capture under ``$REPRO_TRACE_DIR/xprof`` — see :func:`xprof`.
"""
from __future__ import annotations

import atexit
import functools
import json
import os
import sys
import threading
import time
from contextlib import contextmanager
from pathlib import Path

#: bump when the trace line format changes incompatibly
TRACE_SCHEMA = 1

ENV_TRACE = "REPRO_TRACE"
ENV_TRACE_DIR = "REPRO_TRACE_DIR"
ENV_TRACE_TAG = "REPRO_TRACE_TAG"
ENV_XPROF = "REPRO_TRACE_XPROF"

DEFAULT_TRACE_DIR = "trace"
DEFAULT_TAG = "main"


def trace_path(root: str | Path, tag: str, pid: int) -> Path:
    """The trace file a process with this (root, tag, pid) writes."""
    return Path(root) / f"trace-{tag}-{pid}.jsonl"


class _NoopSpan:
    """Disabled-path singleton: no-op context manager AND decorator."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def __call__(self, fn):
        return fn


NOOP = _NoopSpan()


class _Tracer:
    """One per process: owns the trace file, the clock anchors, nesting."""

    def __init__(self, root: str | Path, tag: str):
        self.root = Path(root)
        self.tag = tag
        self.pid = os.getpid()
        self.t0_unix_ns = time.time_ns()
        self.t0_perf_ns = time.perf_counter_ns()
        self._fh = None
        self._lock = threading.Lock()
        self._tls = threading.local()

    @property
    def path(self) -> Path:
        return trace_path(self.root, self.tag, self.pid)

    # -- nesting (thread-local) ---------------------------------------------

    def push(self) -> int:
        d = getattr(self._tls, "depth", 0)
        self._tls.depth = d + 1
        return d

    def pop(self) -> None:
        self._tls.depth = max(0, getattr(self._tls, "depth", 1) - 1)

    def depth(self) -> int:
        return getattr(self._tls, "depth", 0)

    # -- sink ---------------------------------------------------------------

    def _file(self):
        if self._fh is None:
            self.root.mkdir(parents=True, exist_ok=True)
            fh = open(self.path, "a", buffering=1)
            fh.write(json.dumps({
                "kind": "meta", "schema": TRACE_SCHEMA, "pid": self.pid,
                "tag": self.tag, "t0_unix_ns": self.t0_unix_ns,
                "t0_perf_ns": self.t0_perf_ns,
                "argv": sys.argv[:4],
            }, sort_keys=True) + "\n")
            self._fh = fh
            atexit.register(self.close)
        return self._fh

    def emit(self, kind: str, name: str, ts: int, dur: int, depth: int,
             attrs: dict) -> None:
        if os.getpid() != self.pid:
            return  # forked child: its spans belong to a tracer it never made
        rec = {"kind": kind, "name": name, "ts": ts, "dur": dur,
               "pid": self.pid, "tid": threading.get_ident(),
               "depth": depth}
        if attrs:
            rec["args"] = attrs
        line = json.dumps(rec, sort_keys=True, default=str) + "\n"
        with self._lock:
            self._file().write(line)

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


class _Span:
    """Enabled-path span: times the ``with`` body, emits at exit."""

    __slots__ = ("_t", "name", "attrs", "_t0", "_depth")

    def __init__(self, tracer: _Tracer, name: str, attrs: dict):
        self._t = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        self._depth = self._t.push()
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, *exc):
        dur = time.perf_counter_ns() - self._t0
        self._t.pop()
        attrs = self.attrs
        if exc_type is not None:
            attrs = {**attrs, "error": exc_type.__name__}
        self._t.emit("span", self.name, self._t0, dur, self._depth, attrs)
        return False

    def __call__(self, fn):
        name, attrs = self.name, self.attrs

        @functools.wraps(fn)
        def wrapped(*a, **k):
            with span(name, **attrs):
                return fn(*a, **k)

        return wrapped


_TRACER: _Tracer | None = None


def refresh() -> None:
    """(Re-)read the ``REPRO_TRACE*`` env vars and swap the tracer.

    Processes pick the config up at import; tests (and anything that
    mutates the env mid-process) call this to apply a change.
    """
    global _TRACER
    if os.environ.get(ENV_TRACE) == "1":
        root = os.environ.get(ENV_TRACE_DIR) or DEFAULT_TRACE_DIR
        tag = os.environ.get(ENV_TRACE_TAG) or DEFAULT_TAG
        cur = _TRACER
        if (cur is None or cur.pid != os.getpid()
                or (str(cur.root), cur.tag) != (str(Path(root)), tag)):
            if cur is not None:
                cur.close()
            _TRACER = _Tracer(root, tag)
    else:
        if _TRACER is not None:
            _TRACER.close()
        _TRACER = None


refresh()


def enabled() -> bool:
    return _TRACER is not None


def current_path() -> Path | None:
    """This process's trace file (None when tracing is disabled)."""
    return _TRACER.path if _TRACER is not None else None


def current_dir() -> Path | None:
    return _TRACER.root if _TRACER is not None else None


def span(name: str, **attrs):
    """A span named ``name`` — context manager or decorator.

    Disabled, returns the shared no-op immediately (the fast path the
    overhead test gates).  ``attrs`` must be JSON-friendly scalars;
    anything else is stringified.
    """
    t = _TRACER
    if t is None:
        return NOOP
    return _Span(t, name, attrs)


def instant(name: str, **attrs) -> None:
    """A zero-duration marker event (rendered as an arrow in Perfetto)."""
    t = _TRACER
    if t is None:
        return
    t.emit("instant", name, time.perf_counter_ns(), 0, t.depth(), attrs)


# ---------------------------------------------------------------------------
# Optional jax.profiler capture (REPRO_TRACE_XPROF)
# ---------------------------------------------------------------------------

_xprof_captured = False


@contextmanager
def xprof(label: str):
    """Capture a ``jax.profiler`` trace around the first matching trial.

    Active only when ``REPRO_TRACE_XPROF`` is set: the value ``1``
    matches any label, anything else matches as a substring.  At most
    one capture per process (profiler sessions do not nest), written to
    ``<trace dir>/xprof``.  Profiler failures degrade to a plain pass-
    through — telemetry must never take a trial down.
    """
    global _xprof_captured
    pattern = os.environ.get(ENV_XPROF)
    if (not pattern or _xprof_captured
            or (pattern != "1" and pattern not in label)):
        yield
        return
    _xprof_captured = True
    root = current_dir() or Path(os.environ.get(ENV_TRACE_DIR)
                                 or DEFAULT_TRACE_DIR)
    sess = None
    try:
        import jax
        sess = jax.profiler.trace(str(root / "xprof"))
        sess.__enter__()
    except Exception:
        sess = None
    try:
        with span("obs.xprof", label=label):
            yield
    finally:
        if sess is not None:
            try:
                sess.__exit__(None, None, None)
            except Exception:
                pass
