"""Basic layers: RMSNorm, rotary embeddings, FFN variants, embedding."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import param as pm


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * w


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rotary(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """Apply RoPE.  x: [B, S, H, hd]; positions: [B, S] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# FFN (gated SiLU / squared-ReLU) — hidden dim sharded over "model"
# ---------------------------------------------------------------------------


def init_ffn(key, d_model: int, d_ff: int, dtype, *, gated: bool = True,
             fsdp: bool = False):
    ks = jax.random.split(key, 3)
    fa = ("data", "pod") if fsdp else None  # pod joins FSDP on multi-pod meshes
    params = {
        "w_up": pm.normal(ks[0], (d_model, d_ff), d_model ** -0.5, dtype),
        "w_down": pm.normal(ks[1], (d_ff, d_model), d_ff ** -0.5, dtype),
    }
    specs = {"w_up": P(fa, "model"), "w_down": P("model", fa)}
    if gated:
        params["w_gate"] = pm.normal(ks[2], (d_model, d_ff), d_model ** -0.5, dtype)
        specs["w_gate"] = P(fa, "model")
    return params, specs


def ffn(x: jax.Array, p: dict, *, gated: bool = True) -> jax.Array:
    h = x @ p["w_up"]
    if gated:
        h = jax.nn.silu(x @ p["w_gate"]) * h
    else:
        h = jnp.square(jax.nn.relu(h))  # squared-ReLU (nemotron family)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding (vocab sharded over "model")
# ---------------------------------------------------------------------------


def init_embed(key, vocab: int, d_model: int, dtype):
    emb = pm.normal(key, (vocab, d_model), d_model ** -0.5, dtype)
    return emb, P("model", None)


def embed(tokens: jax.Array, emb: jax.Array) -> jax.Array:
    return jnp.take(emb, tokens, axis=0)


def chunked_softmax_xent(
    h: jax.Array,        # [B, S, d]  final hidden states
    emb: jax.Array,      # [V, d]     tied unembedding
    labels: jax.Array,   # [B, S]     int32
    *,
    chunk: int = 256,
    batch_spec=None,
) -> jax.Array:
    """Mean next-token cross-entropy, computed in sequence chunks so the
    [B, chunk, V] logits block is the peak — never the full [B, S, V]."""
    b, s, d = h.shape
    v = emb.shape[0]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    hc = h.reshape(b, s // chunk, chunk, d).swapaxes(0, 1)      # [C, B, c, d]
    lc = labels.reshape(b, s // chunk, chunk).swapaxes(0, 1)    # [C, B, c]

    def step(total, xs):
        hx, lx = xs
        logits = (hx @ emb.T).astype(jnp.float32)               # [B, c, V]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, lx[..., None].astype(jnp.int32), axis=-1
        )[..., 0]
        return total + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (hc, lc))
    return total / (b * s)
