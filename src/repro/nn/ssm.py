"""Mamba2 (SSD) mixer — chunked-parallel training form + recurrent decode.

State-space recurrence per head (A scalar per head, shared B/C projections):

    h_t = exp(A * dt_t) * h_{t-1} + (dt_t * B_t) (x)otimes x_t
    y_t = C_t . h_t + D * x_t

Training uses the SSD chunked decomposition (Dao & Gu 2024): within a chunk
of length Q the recurrence is a masked [Q, Q] matmul (MXU work); across
chunks a lax.scan carries the O(1) state [H, d_state, d_head] — this is what
makes ``long_500k`` run where quadratic attention cannot.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import param as pm


def init_mamba2(key, d_model: int, d_state: int, dtype, *,
                expand: int = 2, head_dim: int = 64, conv_width: int = 4):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 5)
    params = {
        # fused input projection: [z, x, B, C, dt]
        "w_in": pm.normal(ks[0], (d_model, 2 * d_inner + 2 * d_state + n_heads),
                          d_model ** -0.5, dtype),
        "conv": pm.normal(ks[1], (conv_width, d_inner + 2 * d_state), 0.5, dtype),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "a_log": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "w_out": pm.normal(ks[2], (d_inner, d_model), d_inner ** -0.5, dtype),
    }
    specs = {
        "w_in": P(None, "model"),
        "conv": P(None, "model"),
        "dt_bias": P(None,),
        "a_log": P(None,),
        "d_skip": P(None,),
        "w_out": P("model", None),
    }
    meta = dict(d_inner=d_inner, n_heads=n_heads, head_dim=head_dim,
                d_state=d_state, conv_width=conv_width)
    return params, specs, meta


def _split_proj(xp, d_inner, d_state, n_heads):
    z = xp[..., :d_inner]
    xbc = xp[..., d_inner: 2 * d_inner + 2 * d_state]
    dt = xp[..., 2 * d_inner + 2 * d_state:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, *, state=None):
    """Depthwise causal conv1d.  xbc [B,S,C]; conv_w [W,C].

    With ``state`` ([B, W-1, C], decode path) returns (y, new_state)."""
    w = conv_w.shape[0]
    if state is not None:
        buf = jnp.concatenate([state, xbc], axis=1)       # [B, W-1+S, C]
        new_state = buf[:, -(w - 1):, :]
    else:
        buf = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
        new_state = None
    ys = sum(buf[:, i: i + xbc.shape[1], :] * conv_w[i] for i in range(w))
    return jax.nn.silu(ys), new_state


def mamba2(
    x: jax.Array,      # [B, S, d_model]
    p: dict,
    meta: dict,
    *,
    chunk: int = 256,
    state: jax.Array | None = None,     # decode: [B, H, d_state, d_head]
    conv_state: jax.Array | None = None,
):
    """Returns (y [B,S,d_model], (state, conv_state) if decoding else None)."""
    b, s, _ = x.shape
    di, nh, hd, ds = (meta["d_inner"], meta["n_heads"], meta["head_dim"],
                      meta["d_state"])
    xp = x @ p["w_in"]
    z, xbc, dt = _split_proj(xp, di, ds, nh)
    decode = state is not None
    xbc_raw = xbc
    xbc, new_conv = _causal_conv(xbc, p["conv"],
                                 state=conv_state if decode else None)
    xs = xbc[..., :di].reshape(b, s, nh, hd)
    Bm = xbc[..., di: di + ds]                            # [B,S,ds]
    Cm = xbc[..., di + ds:]                               # [B,S,ds]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [B,S,H]
    a = -jnp.exp(p["a_log"])                              # [H] (negative)
    log_decay = a * dt                                    # [B,S,H]

    if decode:  # s == 1: one recurrence step
        dec = jnp.exp(log_decay)[:, 0, :, None, None]     # [B,H,1,1]
        dbx = jnp.einsum("bh,bn,bhp->bhnp", dt[:, 0], Bm[:, 0],
                         xs[:, 0].astype(jnp.float32))
        new_state = dec * state + dbx
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), new_state)
        y = y + p["d_skip"][None, :, None] * xs[:, 0].astype(jnp.float32)
        y = y.reshape(b, 1, di).astype(x.dtype)
        out = (y * jax.nn.silu(z)) @ p["w_out"]
        return out, (new_state, new_conv)

    # ---- chunked SSD ----
    chunk = min(chunk, s)
    while s % chunk:         # largest divisor of s not above the request
        chunk -= 1
    nchunk = s // chunk

    def reshape_c(t):  # [B,S,...] -> [C, B, Q, ...]
        return t.reshape(b, nchunk, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs_c, B_c, C_c = map(reshape_c, (xs, Bm, Cm))
    ld_c = reshape_c(log_decay)                           # [C,B,Q,H]
    dt_c = reshape_c(dt)

    h0 = jnp.zeros((b, nh, ds, hd), jnp.float32)

    def step(h, xs_):
        xq, Bq, Cq, ldq, dtq = xs_                        # per-chunk blocks
        # cumulative decays (fp32)
        Lq = jnp.cumsum(ldq, axis=1)                      # [B,Q,H]
        # intra-chunk: scores[t,s] = C_t.B_s * exp(L_t - L_s) * dt_s, s<=t
        cb = jnp.einsum("btn,bsn->bts", Cq.astype(jnp.float32),
                        Bq.astype(jnp.float32))           # [B,Q,Q]
        ldiff = Lq[:, :, None, :] - Lq[:, None, :, :]     # [B,Q,Q,H]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        m = jnp.where(tri[None, :, :, None], jnp.exp(ldiff), 0.0)
        scores = cb[..., None] * m * dtq[:, None, :, :]   # [B,t,s,H]
        y = jnp.einsum("btsh,bshp->bthp", scores, xq.astype(jnp.float32))
        # inter-chunk: y += C_t . (exp(L_t) h_in)
        y += jnp.einsum("btn,bhnp,bth->bthp", Cq.astype(jnp.float32), h,
                        jnp.exp(Lq))
        # state update: h_out = exp(L_Q) h_in + sum_s exp(L_Q - L_s) dt_s B_s x_s
        last = Lq[:, -1:, :]                              # [B,1,H]
        w_s = jnp.exp(last - Lq) * dtq                    # [B,Q,H]
        h_new = (jnp.exp(last[:, 0, :])[:, :, None, None] * h +
                 jnp.einsum("bsh,bsn,bshp->bhnp", w_s, Bq.astype(jnp.float32),
                            xq.astype(jnp.float32)))
        y = y + p["d_skip"][None, None, :, None] * xq.astype(jnp.float32)
        return h_new, y.astype(x.dtype)

    step = jax.checkpoint(step,
                          policy=jax.checkpoint_policies.nothing_saveable)
    h_last, ys = jax.lax.scan(step, h0, (xs_c, B_c, C_c, ld_c, dt_c))
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    # final recurrent state + conv history -> decoding can continue from here
    conv_tail = xbc_raw[:, -(p["conv"].shape[0] - 1):, :]
    return (y * jax.nn.silu(z)) @ p["w_out"], (h_last, conv_tail)


def init_decode_state(b, meta, dtype=jnp.float32):
    h = jnp.zeros((b, meta["n_heads"], meta["d_state"], meta["head_dim"]),
                  jnp.float32)
    conv = jnp.zeros((b, meta["conv_width"] - 1,
                      meta["d_inner"] + 2 * meta["d_state"]), dtype)
    return h, conv
