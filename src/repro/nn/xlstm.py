"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) + sLSTM (scalar
memory, sequential scan with exponential gating).

mLSTM is linear attention with per-step scalar forget/input gates:

    C_t = f_t C_{t-1} + i_t v_t k_t^T        (matrix state  [dv, dk])
    n_t = f_t n_{t-1} + i_t k_t              (normalizer    [dk])
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)

Training uses the same chunked decomposition as SSD (ssm.py): intra-chunk
masked [Q, Q] matmuls + O(1) carried state, so xLSTM runs the ``long_500k``
shape.  The normalizer rides along as an extra value column.  We use
f = sigmoid(f~), i = exp(min(i~, 8)) — bounded gates instead of the paper's
running-max stabilizer (simplification recorded in DESIGN.md).

sLSTM keeps per-head recurrent weights and exponential gating with the
running-max stabilizer, scanned over time (inherently sequential — the
paper's own characterization).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import param as pm
from repro.nn import layers


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, d_model: int, n_heads: int, dtype, *, proj_factor: int = 2):
    d_inner = proj_factor * d_model
    hd = d_inner // n_heads
    ks = jax.random.split(key, 6)
    params = {
        "w_up": pm.normal(ks[0], (d_model, 2 * d_inner), d_model ** -0.5, dtype),
        "w_qkv": pm.normal(ks[1], (d_inner, 3 * d_inner), d_inner ** -0.5, dtype),
        "w_gates": pm.normal(ks[2], (d_inner, 2 * n_heads), d_inner ** -0.5,
                             jnp.float32),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((n_heads,)), 3.0 * jnp.ones((n_heads,))]  # i~, f~ init
        ),
        "w_down": pm.normal(ks[3], (d_inner, d_model), d_inner ** -0.5, dtype),
    }
    specs = {
        # w_qkv column-parallel (output/head_dim sharded): its input-sharded
        # row-parallel form psum'd an 800 MB [B,S,3*d_inner] block per layer
        # (hillclimb-2 iteration 5; w_down stays row-parallel — its psum of
        # [B,S,d_model] is the standard Megatron reduce)
        "w_up": P(None, "model"), "w_qkv": P(None, "model"),
        "w_gates": P("model", None), "gate_bias": P(None,),
        "w_down": P("model", None),
    }
    meta = dict(d_inner=d_inner, n_heads=n_heads, head_dim=hd)
    return params, specs, meta


def mlstm(x, p, meta, *, chunk: int = 256, state=None):
    """x [B,S,d]; state (decode): (C [B,H,dv+1,dk], ) ; returns (y, state')."""
    b, s, _ = x.shape
    nh, hd = meta["n_heads"], meta["head_dim"]
    di = meta["d_inner"]
    up = x @ p["w_up"]
    xi, z = up[..., :di], up[..., di:]
    qkv = xi @ p["w_qkv"]
    q = qkv[..., :di].reshape(b, s, nh, hd)
    k = qkv[..., di: 2 * di].reshape(b, s, nh, hd) * (hd ** -0.5)
    v = qkv[..., 2 * di:].reshape(b, s, nh, hd)
    gates = xi @ p["w_gates"] + p["gate_bias"]
    i_g = jnp.exp(jnp.minimum(gates[..., :nh].astype(jnp.float32), 8.0))
    log_f = jax.nn.log_sigmoid(gates[..., nh:].astype(jnp.float32))  # [B,S,H]

    # augment v with ones column -> normalizer rides in the state
    # (kept in the native activation dtype: intra-chunk matmuls run bf16 in
    # production with fp32 accumulation — hillclimb-2 iteration 3)
    v_aug = jnp.concatenate(
        [v, jnp.ones((b, s, nh, 1), v.dtype)], axis=-1)   # [B,S,H,hd+1]

    if state is not None:  # decode: single recurrence step
        C = state                                          # [B,H,hd+1,hd]
        dec = jnp.exp(log_f)[:, 0, :, None, None]
        upd = jnp.einsum("bh,bhp,bhn->bhpn", i_g[:, 0], v_aug[:, 0],
                         k[:, 0].astype(jnp.float32))
        C = dec * C + upd
        hq = jnp.einsum("bhpn,bhn->bhp", C, q[:, 0].astype(jnp.float32))
        y, n_dot = hq[..., :hd], hq[..., hd]
        y = y / jnp.maximum(jnp.abs(n_dot), 1.0)[..., None]
        y = y.reshape(b, 1, di).astype(x.dtype)
        out = (y * jax.nn.silu(z)) @ p["w_down"]
        return out, C

    chunk = min(chunk, s)
    while s % chunk:         # largest divisor of s not above the request
        chunk -= 1
    nchunk = s // chunk

    # one layout change per tensor up front: everything in the chunk body
    # lives in [B, H, Q, *] so no einsum needs a transposed operand
    # (hillclimb-2: the mixed-layout body spent ~50% of its HBM traffic on
    # transpose copies — EXPERIMENTS.md §Perf)
    def rc(t):  # [B,S,H,*] or [B,S,H] -> [C, B, H, Q, *]
        t = t.reshape(b, nchunk, chunk, *t.shape[2:])
        perm = (1, 0, 3, 2, *range(4, t.ndim))
        return t.transpose(perm)

    qc, kc, vc = map(rc, (q, k, v_aug))                    # [C,B,H,Q,n/p]
    ic, lfc = map(rc, (i_g, log_f))                        # [C,B,H,Q]
    C0 = jnp.zeros((b, nh, hd + 1, hd), jnp.float32)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(C, xs_):
        qq, kk, vv, ii, lf = xs_                           # [B,H,Q,*] native
        acc = jnp.float32
        Lq = jnp.cumsum(lf, axis=2)                        # [B,H,Q] fp32
        qk = jnp.einsum("bhtn,bhsn->bhts", qq, kk,
                        preferred_element_type=acc)
        ldiff = Lq[:, :, :, None] - Lq[:, :, None, :]      # [B,H,t,s]
        m = jnp.where(tri[None, None], jnp.exp(ldiff), 0.0)
        scores = qk * m * ii[:, :, None, :]                # i_s weight
        h = jnp.einsum("bhts,bhsp->bhtp", scores.astype(vv.dtype), vv,
                       preferred_element_type=acc)
        h += jnp.einsum("bhtn,bhpn->bhtp", qq, C.astype(qq.dtype),
                        preferred_element_type=acc) * jnp.exp(Lq)[..., None]
        last = Lq[:, :, -1:]
        w_s = jnp.exp(last - Lq) * ii                      # [B,H,Q]
        C_new = (jnp.exp(last[..., 0])[:, :, None, None] * C +
                 jnp.einsum("bhsp,bhsn->bhpn",
                            vv * w_s[..., None].astype(vv.dtype), kk,
                            preferred_element_type=acc))
        y, n_dot = h[..., :hd], h[..., hd]
        y = y / jnp.maximum(jnp.abs(n_dot), 1.0)[..., None]
        return C_new, y.astype(x.dtype)                    # y [B,H,Q,p]

    step = jax.checkpoint(step,
                          policy=jax.checkpoint_policies.nothing_saveable)
    C_last, ys = jax.lax.scan(step, C0, (qc, kc, vc, ic, lfc))
    # [C,B,H,Q,p] -> [B, S, H*p] in one transpose
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, di)
    return (y * jax.nn.silu(z)) @ p["w_down"], C_last


def init_mlstm_state(b, meta):
    return jnp.zeros((b, meta["n_heads"], meta["head_dim"] + 1,
                      meta["head_dim"]), jnp.float32)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, d_model: int, n_heads: int, dtype):
    hd = d_model // n_heads
    ks = jax.random.split(key, 3)
    params = {
        "w_x": pm.normal(ks[0], (d_model, 4 * d_model), d_model ** -0.5, dtype),
        "r_h": pm.normal(ks[1], (n_heads, hd, 4 * hd), hd ** -0.5, dtype),
        "bias": jnp.zeros((4 * d_model,), jnp.float32),
        "w_out": pm.normal(ks[2], (d_model, d_model), d_model ** -0.5, dtype),
    }
    specs = {"w_x": P(None, "model"), "r_h": P(None, None, "model"),
             "bias": P(None,), "w_out": P("model", None)}
    meta = dict(n_heads=n_heads, head_dim=hd)
    return params, specs, meta


def slstm(x, p, meta, *, state=None):
    """x [B,S,d].  state: (c, n, h, m) each [B,H,hd].  Sequential scan."""
    b, s, d = x.shape
    nh, hd = meta["n_heads"], meta["head_dim"]
    xz = (x @ p["w_x"] + p["bias"].astype(x.dtype))        # [B,S,4d]
    xz = xz.reshape(b, s, 4, nh, hd).swapaxes(0, 1)        # [S,B,4,H,hd]

    if state is None:
        zeros = jnp.zeros((b, nh, hd), jnp.float32)
        state = (zeros, zeros, zeros, jnp.full((b, nh, hd), -1e30))

    def step(carry, xt):
        c, n, h, m = carry
        rec = jnp.einsum("bhi,hij->bhj", h.astype(x.dtype), p["r_h"])
        rec = rec.reshape(b, nh, 4, hd).swapaxes(1, 2)     # [B,4,H,hd]
        pre = (xt + rec).astype(jnp.float32)               # [B,4,H,hd]
        z_t = jnp.tanh(pre[:, 0])
        i_t, f_t = pre[:, 1], pre[:, 2]
        o_t = jax.nn.sigmoid(pre[:, 3])
        m_new = jnp.maximum(f_t + m, i_t)                  # stabilizer
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(f_t + m - m_new)
        c_new = f_p * c + i_p * z_t
        n_new = f_p * n + i_p
        h_new = o_t * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new.astype(x.dtype)

    new_state, hs = jax.lax.scan(step, state, xz)
    y = hs.swapaxes(0, 1).reshape(b, s, d)                 # [B,S,d]
    return y @ p["w_out"], new_state
