"""Model assembly: per-family blocks + scan-over-layers forward passes.

Families (ARCHITECTURES block):
  dense    pre-norm GQA attention + FFN (gated-SiLU or squared-ReLU)
  moe      attention + top-k expert FFN
  audio    dense backbone over precomputed frame embeddings (stub frontend)
  hybrid   Mamba2 backbone + periodically-applied *shared* attention block
  ssm      xLSTM: scanned superblocks of (7 mLSTM + 1 sLSTM)
  vlm      dense decoder + cross-attention to patch embeddings every 5 layers

All families scan over (stacks of) layers so HLO size is depth-independent,
apply jax.checkpoint to the scanned body (remat), and thread a ``shard``
callback for activation sharding constraints (sequence parallelism etc.).
Decode paths carry per-layer caches/states stacked on the layer axis.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import attention, layers, moe, param as pm, ssm, xlstm

Array = jax.Array
NOSHARD = lambda x, spec: x  # noqa: E731


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | audio | hybrid | ssm | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    # family extras
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_groups: int = 0         # group-local dispatch (0 -> single group)
    moe_model_shards: int = 1   # model-axis size (gathered-experts groups)
    ssm_state: int = 0
    window: int | None = None   # sliding-window attention
    cross_every: int = 0        # vlm: one cross-attn layer per this many
    n_memory: int = 0           # vlm/audio: #frontend embeddings
    ffn_gated: bool = True
    fsdp: bool = False
    seq_shard: bool = False     # sequence-parallel residual stream
    param_dtype: Any = jnp.bfloat16
    head_dim: int = 0
    attn_chunk: int = 1024      # kv chunk for chunked attention
    loss_chunk: int = 256       # sequence chunk for the xent loss
    ssm_chunk: int = 256
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def emb_in(self) -> bool:
        """True if the input is precomputed embeddings (stub frontend)."""
        return self.family == "audio"


# ---------------------------------------------------------------------------
# Dense / MoE / VLM blocks
# ---------------------------------------------------------------------------


def init_attn_block(cfg: ArchConfig, key, *, with_moe=False, cross=False):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    attn_p, attn_s = attention.init_attention(
        k1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd, cfg.param_dtype,
        fsdp=cfg.fsdp)
    n1, n1s = pm.make_norm(cfg.d_model, cfg.param_dtype)
    n2, n2s = pm.make_norm(cfg.d_model, cfg.param_dtype)
    params = {"attn": attn_p, "norm1": n1, "norm2": n2}
    specs = {"attn": attn_s, "norm1": n1s, "norm2": n2s}
    if with_moe:
        m_p, m_s = moe.init_moe(k2, cfg.d_model, cfg.d_ff, cfg.moe_experts,
                                cfg.param_dtype, fsdp=cfg.fsdp)
        params["moe"], specs["moe"] = m_p, m_s
    else:
        f_p, f_s = layers.init_ffn(k2, cfg.d_model, cfg.d_ff, cfg.param_dtype,
                                   gated=cfg.ffn_gated, fsdp=cfg.fsdp)
        params["ffn"], specs["ffn"] = f_p, f_s
    return params, specs


def attn_block(
    x, p, cfg: ArchConfig, positions, *, shard=NOSHARD, cache=None,
    memory=None, cross=False,
):
    """Pre-norm block.  Returns (x, new_cache)."""
    h = layers.rms_norm(x, p["norm1"])
    if cross:
        a = attention.cross_attention(
            h, memory, p["attn"], n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            head_dim=cfg.hd)
        new_cache = cache
    else:
        a, new_cache = attention.self_attention(
            h, p["attn"], n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
            positions=positions, causal=True, window=cfg.window, cache=cache,
            chunk_q=cfg.attn_chunk, shard=shard)
    x = shard(x + a, P("batch", "seq", None))
    h = layers.rms_norm(x, p["norm2"])
    if "moe" in p:
        b, s, d = h.shape
        out, aux = moe.moe_ffn(h.reshape(b * s, d), p["moe"],
                               top_k=cfg.moe_top_k,
                               groups=cfg.moe_groups or 1,
                               model_shards=cfg.moe_model_shards, shard=shard)
        f = out.reshape(b, s, d)
    else:
        f = layers.ffn(h, p["ffn"], gated=cfg.ffn_gated)
    x = shard(x + f, P("batch", "seq", None))
    return x, new_cache


# ---------------------------------------------------------------------------
# Parameter init (all families)
# ---------------------------------------------------------------------------


def init_params(cfg: ArchConfig, key):
    """Returns (params, specs)."""
    keys = jax.random.split(key, cfg.n_layers + 4)
    params: dict = {}
    specs: dict = {}

    if not cfg.emb_in():
        emb, emb_s = layers.init_embed(keys[-1], cfg.vocab, cfg.d_model,
                                       cfg.param_dtype)
        params["embed"], specs["embed"] = emb, emb_s
    else:  # stub frontend: separate output head over the small codec vocab
        head = pm.normal(keys[-1], (cfg.d_model, cfg.vocab),
                         cfg.d_model ** -0.5, cfg.param_dtype)
        params["head"], specs["head"] = head, P(None, "model")

    fnorm, fnorm_s = pm.make_norm(cfg.d_model, cfg.param_dtype)
    params["final_norm"], specs["final_norm"] = fnorm, fnorm_s

    fam = cfg.family
    if fam in ("dense", "audio"):
        pairs = [init_attn_block(cfg, keys[i]) for i in range(cfg.n_layers)]
        params["layers"], specs["layers"] = pm.stack_layers(pairs)

    elif fam == "moe":
        pairs = [init_attn_block(cfg, keys[i], with_moe=True)
                 for i in range(cfg.n_layers)]
        params["layers"], specs["layers"] = pm.stack_layers(pairs)

    elif fam == "vlm":
        ce = cfg.cross_every
        n_super = cfg.n_layers // ce
        self_pairs = [init_attn_block(cfg, keys[i])
                      for i in range(n_super * (ce - 1))]
        ck = jax.random.split(keys[-2], n_super)
        cross_pairs = [init_attn_block(cfg, ck[i], cross=True)
                       for i in range(n_super)]
        # restack: [n_super, ce-1, ...] for the two-level scan
        sp, ss_ = pm.stack_layers(self_pairs)
        sp = jax.tree.map(
            lambda x: x.reshape(n_super, ce - 1, *x.shape[1:]), sp)
        ss_ = jax.tree.map(lambda s: P(None, *s) if isinstance(s, P) else s,
                           ss_, is_leaf=lambda x: isinstance(x, P))
        cp, cs = pm.stack_layers(cross_pairs)
        params["self_layers"], specs["self_layers"] = sp, ss_
        params["cross_layers"], specs["cross_layers"] = cp, cs

    elif fam == "hybrid":  # zamba2: mamba backbone + one shared attn block
        n_sb, per = cfg.n_layers // 6, 6          # 6 superblocks of 6 + rest
        rest = cfg.n_layers - n_sb * per
        mk = jax.random.split(keys[0], cfg.n_layers)
        pairs = []
        for i in range(cfg.n_layers):
            p_, s_, meta = ssm.init_mamba2(mk[i], cfg.d_model, cfg.ssm_state,
                                           cfg.param_dtype)
            n_, ns_ = pm.make_norm(cfg.d_model, cfg.param_dtype)
            pairs.append(({"mamba": p_, "norm": n_},
                          {"mamba": s_, "norm": ns_}))
        main, main_s = pm.stack_layers(pairs[: n_sb * per])
        main = jax.tree.map(lambda x: x.reshape(n_sb, per, *x.shape[1:]), main)
        main_s = jax.tree.map(lambda s: P(None, *s) if isinstance(s, P) else s,
                              main_s, is_leaf=lambda x: isinstance(x, P))
        params["mamba_blocks"], specs["mamba_blocks"] = main, main_s
        if rest:
            tail, tail_s = pm.stack_layers(pairs[n_sb * per:])
            params["mamba_tail"], specs["mamba_tail"] = tail, tail_s
        shared, shared_s = init_attn_block(cfg, keys[1])
        params["shared_attn"], specs["shared_attn"] = shared, shared_s

    elif fam == "ssm":  # xLSTM: superblocks of (7 mLSTM + 1 sLSTM)
        per, n_sb = 8, cfg.n_layers // 8
        m_pairs, s_pairs = [], []
        mk = jax.random.split(keys[0], cfg.n_layers)
        for sb in range(n_sb):
            for j in range(per - 1):
                p_, s_, _ = xlstm.init_mlstm(mk[sb * per + j], cfg.d_model,
                                             cfg.n_heads, cfg.param_dtype)
                n_, ns_ = pm.make_norm(cfg.d_model, cfg.param_dtype)
                m_pairs.append(({"mix": p_, "norm": n_},
                                {"mix": s_, "norm": ns_}))
            p_, s_, _ = xlstm.init_slstm(mk[sb * per + per - 1], cfg.d_model,
                                         cfg.n_heads, cfg.param_dtype)
            n_, ns_ = pm.make_norm(cfg.d_model, cfg.param_dtype)
            s_pairs.append(({"mix": p_, "norm": n_}, {"mix": s_, "norm": ns_}))
        mp, ms = pm.stack_layers(m_pairs)
        mp = jax.tree.map(lambda x: x.reshape(n_sb, per - 1, *x.shape[1:]), mp)
        ms = jax.tree.map(lambda s: P(None, *s) if isinstance(s, P) else s,
                          ms, is_leaf=lambda x: isinstance(x, P))
        sp, ss_ = pm.stack_layers(s_pairs)
        params["mlstm_blocks"], specs["mlstm_blocks"] = mp, ms
        params["slstm_blocks"], specs["slstm_blocks"] = sp, ss_

    else:
        raise ValueError(cfg.family)

    return params, specs


# ---------------------------------------------------------------------------
# Forward (training / prefill): returns final hidden states [B, S, d]
# ---------------------------------------------------------------------------


def forward(
    params, cfg: ArchConfig, inputs: dict, *, shard: Callable = NOSHARD,
    mode: str = "train",
):
    """inputs: {"tokens" | "embeddings", optional "memory" [B,M,d]}.

    mode="train"   -> returns final hidden states [B, S, d]
    mode="prefill" -> returns (hidden, cache) where cache matches
                      decode.init_cache's structure (ready for decode_step).
    """
    prefill = mode == "prefill"
    if cfg.emb_in():
        x = inputs["embeddings"].astype(cfg.param_dtype)
    else:
        x = layers.embed(inputs["tokens"], params["embed"])
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = shard(x, P("batch", "seq", None))
    fam = cfg.family
    cache = {}

    def ckpt(f):
        if prefill:
            return f
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.nothing_saveable)

    if fam in ("dense", "moe", "audio"):
        @ckpt
        def body(x, layer_p):
            x, kv = attn_block(x, layer_p, cfg, positions, shard=shard)
            return x, (kv if prefill else None)

        x, kvs = jax.lax.scan(body, x, params["layers"])
        if prefill:
            cache = {"k": kvs[0], "v": kvs[1]}

    elif fam == "vlm":
        memory = inputs["memory"].astype(cfg.param_dtype)

        @ckpt
        def super_body(x, ps):
            self_p, cross_p = ps

            def inner(x, lp):
                x, kv = attn_block(x, lp, cfg, positions, shard=shard)
                return x, (kv if prefill else None)

            x, kvs = jax.lax.scan(inner, x, self_p)
            x, _ = attn_block(x, cross_p, cfg, positions, shard=shard,
                              memory=memory, cross=True)
            return x, kvs

        x, kvs = jax.lax.scan(
            super_body, x, (params["self_layers"], params["cross_layers"]))
        if prefill:
            cache = {"k": kvs[0], "v": kvs[1]}

    elif fam == "hybrid":
        meta = _mamba_meta(cfg)
        shared_p = params["shared_attn"]

        def mamba_layer(x, lp):
            h = layers.rms_norm(x, lp["norm"])
            y, st = ssm.mamba2(h, lp["mamba"], meta, chunk=cfg.ssm_chunk)
            return (shard(x + y, P("batch", "seq", None)),
                    st if prefill else None)

        @ckpt
        def super_body(x, ps):
            x, sts = jax.lax.scan(mamba_layer, x, ps)
            x, kv = attn_block(x, shared_p, cfg, positions, shard=shard)
            return x, ((sts, kv) if prefill else None)

        x, ys = jax.lax.scan(super_body, x, params["mamba_blocks"])
        if prefill:
            (h_st, cv_st), kvs = ys
            cache = {"h": h_st, "conv": cv_st.astype(cfg.param_dtype),
                     "attn_k": kvs[0], "attn_v": kvs[1]}
        if "mamba_tail" in params:
            x, tail = jax.lax.scan(mamba_layer, x, params["mamba_tail"])
            if prefill:
                cache["h_tail"] = tail[0]
                cache["conv_tail"] = tail[1].astype(cfg.param_dtype)

    elif fam == "ssm":
        m_meta = _mlstm_meta(cfg)
        s_meta = _slstm_meta(cfg)

        @ckpt
        def super_body(x, ps):
            mp, sp = ps

            def m_layer(x, lp):
                h = layers.rms_norm(x, lp["norm"])
                y, C = xlstm.mlstm(h, lp["mix"], m_meta, chunk=cfg.ssm_chunk)
                return (shard(x + y, P("batch", "seq", None)),
                        C if prefill else None)

            x, Cs = jax.lax.scan(m_layer, x, mp)
            h = layers.rms_norm(x, sp["norm"])
            y, st = xlstm.slstm(h, sp["mix"], s_meta)
            return (shard(x + y, P("batch", "seq", None)),
                    (Cs, st) if prefill else None)

        x, ys = jax.lax.scan(
            super_body, x, (params["mlstm_blocks"], params["slstm_blocks"]))
        if prefill:
            Cs, (sc, sn, sh, sm) = ys
            cache = {"C": Cs, "s_c": sc, "s_n": sn, "s_h": sh, "s_m": sm}

    else:
        raise ValueError(fam)

    h = layers.rms_norm(x, params["final_norm"])
    return (h, cache) if prefill else h


def _mamba_meta(cfg: ArchConfig):
    d_inner = 2 * cfg.d_model
    return dict(d_inner=d_inner, n_heads=d_inner // 64, head_dim=64,
                d_state=cfg.ssm_state, conv_width=4)


def _mlstm_meta(cfg: ArchConfig):
    d_inner = 2 * cfg.d_model
    return dict(d_inner=d_inner, n_heads=cfg.n_heads,
                head_dim=d_inner // cfg.n_heads)


def _slstm_meta(cfg: ArchConfig):
    return dict(n_heads=cfg.n_heads, head_dim=cfg.d_model // cfg.n_heads)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def loss_fn(params, cfg: ArchConfig, batch: dict, *,
            shard: Callable = NOSHARD) -> Array:
    """Mean next-token cross-entropy (tied embeddings; chunked logits)."""
    h = forward(params, cfg, batch, shard=shard)
    unembed = params["head"].T if cfg.emb_in() else params["embed"]
    return layers.chunked_softmax_xent(
        h, unembed, batch["labels"], chunk=cfg.loss_chunk)
