"""Single-token decode (serve) paths with per-family caches.

Cache layout (stacked on the layer axis so the decode step scans layers):

  dense/moe/audio : k/v caches [L, B, Hkv, S_cache, hd]
  vlm             : self caches [n_super, ce-1, ...] (cross-attn K/V are
                    recomputed from the static memory; precomputing them is
                    a recorded optimization)
  hybrid (zamba2) : mamba2 states [n_sb, per, B, H, ds, hd] + conv states +
                    ONE shared-attn k/v cache (ring-buffered to 4096 beyond
                    64k context — DESIGN.md §Arch-applicability)
  ssm (xlstm)     : mLSTM matrix states + sLSTM (c, n, h, m) states

Spec trees use the axis name "batch" on batch axes; the launcher substitutes
the mesh batch axes (("pod","data") / ("data",)) before lowering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import attention, layers, ssm, transformer, xlstm
from repro.nn.transformer import ArchConfig, NOSHARD, _mamba_meta, _mlstm_meta, _slstm_meta

Array = jax.Array


def _kv_cache(layers_shape, b, n_kv, s, hd, dtype):
    # KV caches shard on the *sequence* axis ("kvseq" -> "model", or the
    # whole mesh when the batch is unshardable, e.g. long_500k with B=1):
    # GQA head counts (8) don't divide the model axis (16), sequence does.
    shape = (*layers_shape, b, n_kv, s, hd)
    zeros = jnp.zeros(shape, dtype)
    spec = P(*(None,) * len(layers_shape), "batch", None, "kvseq", None)
    return (zeros, zeros), ((spec, spec))


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Returns (cache, specs)."""
    fam, dt = cfg.family, cfg.param_dtype
    if fam in ("dense", "moe", "audio"):
        s_cache = min(max_len, cfg.window) if cfg.window else max_len
        (k, v), (ks, vs) = _kv_cache((cfg.n_layers,), batch, cfg.n_kv,
                                     s_cache, cfg.hd, dt)
        return {"k": k, "v": v}, {"k": ks, "v": vs}

    if fam == "vlm":
        ce = cfg.cross_every
        n_super = cfg.n_layers // ce
        (k, v), (ks, vs) = _kv_cache((n_super, ce - 1), batch, cfg.n_kv,
                                     max_len, cfg.hd, dt)
        return {"k": k, "v": v}, {"k": ks, "v": vs}

    if fam == "hybrid":
        meta = _mamba_meta(cfg)
        n_sb, per = cfg.n_layers // 6, 6
        rest = cfg.n_layers - n_sb * per
        h = jnp.zeros((n_sb, per, batch, meta["n_heads"], meta["d_state"],
                       meta["head_dim"]), jnp.float32)
        conv = jnp.zeros((n_sb, per, batch, meta["conv_width"] - 1,
                          meta["d_inner"] + 2 * meta["d_state"]), dt)
        hs = P(None, None, "batch", "model", None, None)
        cs = P(None, None, "batch", None, "model")
        cache = {"h": h, "conv": conv}
        specs = {"h": hs, "conv": cs}
        if rest:
            cache["h_tail"] = jnp.zeros((rest, *h.shape[2:]), jnp.float32)
            cache["conv_tail"] = jnp.zeros((rest, *conv.shape[2:]), dt)
            specs["h_tail"] = P(None, "batch", "model", None, None)
            specs["conv_tail"] = P(None, "batch", None, "model")
        attn_len = max_len if max_len <= 65_536 else 4_096  # ring beyond 64k
        # one KV history per superblock application (weights are shared,
        # activations are not)
        (k, v), (ks, vs) = _kv_cache((n_sb,), batch, cfg.n_kv, attn_len,
                                     cfg.hd, dt)
        cache["attn_k"], cache["attn_v"] = k, v
        specs["attn_k"], specs["attn_v"] = ks, vs
        return cache, specs

    if fam == "ssm":
        m_meta = _mlstm_meta(cfg)
        s_meta = _slstm_meta(cfg)
        per, n_sb = 8, cfg.n_layers // 8
        C = jnp.zeros((n_sb, per - 1, batch, m_meta["n_heads"],
                       m_meta["head_dim"] + 1, m_meta["head_dim"]), jnp.float32)
        sl = jnp.zeros((n_sb, batch, s_meta["n_heads"], s_meta["head_dim"]),
                       jnp.float32)
        cache = {"C": C, "s_c": sl, "s_n": sl, "s_h": sl,
                 "s_m": jnp.full_like(sl, -1e30)}
        # xLSTM has only 4 heads: shard the (large) head_dim axis instead
        cspec = P(None, None, "batch", None, None, "model")
        sspec = P(None, "batch", None, "model")
        specs = {"C": cspec, "s_c": sspec, "s_n": sspec, "s_h": sspec,
                 "s_m": sspec}
        return cache, specs

    raise ValueError(fam)


# ---------------------------------------------------------------------------
# One decode step
# ---------------------------------------------------------------------------


def decode_step(params, cfg: ArchConfig, cache: dict, inputs: dict,
                idx: Array, *, shard=NOSHARD):
    """inputs: {"tokens" [B,1]} or {"embeddings" [B,1,d]} (+"memory" for vlm).

    Returns (logits [B, vocab], new_cache)."""
    fam = cfg.family
    if cfg.emb_in():
        x = inputs["embeddings"].astype(cfg.param_dtype)
    else:
        x = layers.embed(inputs["tokens"], params["embed"])
    b = x.shape[0]
    positions = jnp.full((b, 1), idx, jnp.int32)
    new_cache = dict(cache)

    if fam in ("dense", "moe", "audio"):
        def body(x, xs):
            lp, kc, vc = xs
            x, (k2, v2, _) = transformer.attn_block(
                x, lp, cfg, positions, shard=shard, cache=(kc, vc, idx))
            return x, (k2, v2)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache.update(k=k_new, v=v_new)

    elif fam == "vlm":
        memory = inputs["memory"].astype(cfg.param_dtype)

        def super_body(x, xs):
            (self_p, cross_p), kc, vc = xs

            def inner(x, xs2):
                lp, k1, v1 = xs2
                x, (k2, v2, _) = transformer.attn_block(
                    x, lp, cfg, positions, shard=shard, cache=(k1, v1, idx))
                return x, (k2, v2)

            x, (k2, v2) = jax.lax.scan(inner, x, (self_p, kc, vc))
            x, _ = transformer.attn_block(x, cross_p, cfg, positions,
                                          shard=shard, memory=memory,
                                          cross=True)
            return x, (k2, v2)

        x, (k_new, v_new) = jax.lax.scan(
            super_body, x,
            ((params["self_layers"], params["cross_layers"]),
             cache["k"], cache["v"]))
        new_cache.update(k=k_new, v=v_new)

    elif fam == "hybrid":
        meta = _mamba_meta(cfg)

        def mamba_layer(x, xs):
            lp, h, cv = xs
            hnorm = layers.rms_norm(x, lp["norm"])
            y, (h2, cv2) = ssm.mamba2(hnorm, lp["mamba"], meta,
                                      state=h, conv_state=cv)
            return x + y, (h2, cv2)

        def super_body(x, xs):
            ps, h, cv, ak, av = xs
            x, (h2, cv2) = jax.lax.scan(mamba_layer, x, (ps, h, cv))
            # shared attention block (weight-tied); ring cache handles the
            # 4096-window long-context mode transparently
            x, (ak2, av2, _) = transformer.attn_block(
                x, params["shared_attn"], cfg, positions, shard=shard,
                cache=(ak, av, idx))
            return x, (h2, cv2, ak2, av2)

        x, (h_new, conv_new, ak, av) = jax.lax.scan(
            super_body, x,
            (params["mamba_blocks"], cache["h"], cache["conv"],
             cache["attn_k"], cache["attn_v"]))
        new_cache.update(h=h_new, conv=conv_new, attn_k=ak, attn_v=av)
        if "mamba_tail" in params:
            x, (ht, cvt) = jax.lax.scan(
                mamba_layer, x,
                (params["mamba_tail"], cache["h_tail"], cache["conv_tail"]))
            new_cache.update(h_tail=ht, conv_tail=cvt)

    elif fam == "ssm":
        m_meta = _mlstm_meta(cfg)
        s_meta = _slstm_meta(cfg)

        def m_layer(x, xs):
            lp, C = xs
            h = layers.rms_norm(x, lp["norm"])
            y, C2 = xlstm.mlstm(h, lp["mix"], m_meta, state=C)
            return x + y, C2

        def super_body(x, xs):
            mp, sp, C, sc, sn, sh, sm = xs
            x, C2 = jax.lax.scan(m_layer, x, (mp, C))
            h = layers.rms_norm(x, sp["norm"])
            y, (sc2, sn2, sh2, sm2) = xlstm.slstm(h, sp["mix"], s_meta,
                                                  state=(sc, sn, sh, sm))
            return x + y, (C2, sc2, sn2, sh2, sm2)

        x, (C_new, sc, sn, sh, sm) = jax.lax.scan(
            super_body, x,
            (params["mlstm_blocks"], params["slstm_blocks"], cache["C"],
             cache["s_c"], cache["s_n"], cache["s_h"], cache["s_m"]))
        new_cache.update(C=C_new, s_c=sc, s_n=sn, s_h=sh, s_m=sm)

    else:
        raise ValueError(fam)

    h = layers.rms_norm(x, params["final_norm"])              # [B, 1, d]
    unembed = params["head"].T if cfg.emb_in() else params["embed"]
    logits = (h[:, 0] @ unembed.T).astype(jnp.float32)        # [B, V]
    return logits, new_cache
