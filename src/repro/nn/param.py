"""Parameter pytrees + PartitionSpec rules (no flax in this environment).

Parameters are nested dicts of jnp arrays.  Every ``init_*`` function
returns ``(params, specs)`` — two trees with identical structure, where the
spec tree holds ``jax.sharding.PartitionSpec`` leaves.  Scanned layer stacks
carry a leading layer axis (always unsharded: ``None`` first spec entry).

Sharding rules (DESIGN.md §6):
  vocab/embedding rows     -> "model"
  attention heads          -> "model"
  FFN hidden               -> "model"
  MoE experts              -> "model"   (expert parallelism)
  batch                    -> ("pod", "data") for sync; ("data",) within a
                              pod for async-local (pod axis = replica axis)
  optional FSDP            -> remaining large param axis over "data"
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Any  # nested dict pytree
Specs = Any


@dataclasses.dataclass(frozen=True)
class AxisNames:
    pod: str | None = "pod"
    data: str = "data"
    model: str = "model"

    @property
    def batch_axes(self):
        return (self.pod, self.data) if self.pod else (self.data,)


def normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def make_dense(key, d_in, d_out, dtype, in_spec=None, out_spec="model",
               fsdp_axis=None):
    """Weight [d_in, d_out] with the given axis sharding."""
    w = normal(key, (d_in, d_out), d_in ** -0.5, dtype)
    spec = P(in_spec if in_spec is not None else fsdp_axis, out_spec)
    return w, spec


def make_norm(d, dtype):
    return jnp.ones((d,), dtype), P(None)


def stack_layers(pairs):
    """Stack per-layer (params, specs) into scanned [L, ...] trees."""
    params = [p for p, _ in pairs]
    specs = pairs[0][1]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *params)
    specs = jax.tree.map(
        lambda s: P(None, *s) if isinstance(s, P) else s, specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    return stacked, specs


def tree_specs_to_shardings(specs, mesh):
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def eval_shape_params(init_fn, *args):
    """Shape-only param init (for dry-runs: no host allocation)."""
    return jax.eval_shape(init_fn, *args)
