"""Attention: GQA self-attention (full / sliding-window), cross-attention,
and a memory-bounded chunked ("XLA-flash") formulation used at scale.

Two execution paths share the same math:

* ``kernels/flash_attn`` — the Pallas TPU kernel (runtime path on TPU).
* ``chunked_attention`` here — pure-XLA online-softmax scan over KV chunks;
  this is what the multi-pod dry-run lowers (Pallas cannot compile for the
  CPU placeholder backend), and its HLO is what the roofline reads.  Peak
  memory is O(B*H*Sq*Tk) per chunk instead of O(B*H*Sq*Sk).

Decode path: single-token query against a KV cache (ring buffer for SWA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import param as pm
from repro.nn import layers

NEG_INF = -1e30


def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   dtype, *, fsdp: bool = False):
    ks = jax.random.split(key, 4)
    fa = ("data", "pod") if fsdp else None  # pod joins FSDP on multi-pod meshes
    params = {
        "wq": pm.normal(ks[0], (d_model, n_heads * head_dim), d_model ** -0.5, dtype),
        "wk": pm.normal(ks[1], (d_model, n_kv * head_dim), d_model ** -0.5, dtype),
        "wv": pm.normal(ks[2], (d_model, n_kv * head_dim), d_model ** -0.5, dtype),
        "wo": pm.normal(ks[3], (n_heads * head_dim, d_model),
                        (n_heads * head_dim) ** -0.5, dtype),
    }
    specs = {
        "wq": P(fa, "model"), "wk": P(fa, "model"), "wv": P(fa, "model"),
        "wo": P("model", fa),
    }
    return params, specs


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (pure XLA; the dry-run/roofline path)
# ---------------------------------------------------------------------------


def chunked_attention(
    q: jax.Array,   # [B, Hq, Sq, hd]
    k: jax.Array,   # [B, Hkv, Sk, hd]
    v: jax.Array,   # [B, Hkv, Sk, hd]
    *,
    causal: bool = True,
    window: int | None = None,
    chunk_q: int = 512,
) -> jax.Array:
    """Query-chunked attention with rematerialized chunk bodies.

    Each q chunk attends independently (no carried softmax state), so the
    backward pass recomputes one [B, H, Tq, Sk] score block at a time
    instead of saving every block — peak memory is O(B*H*Tq*Sk), not
    O(B*H*Sq*Sk).  For sliding-window attention the key range per q chunk
    is a *static-size* dynamic slice of window+Tq keys: SWA compute is
    O(Sq * window) — the sub-quadratic path that makes long_500k viable.
    """
    b, hq, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if hkv != hq:  # GQA: materialize kv per query head (kv tensors are small)
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = hd ** -0.5
    chunk_q = min(chunk_q, sq)
    while sq % chunk_q:      # largest divisor of sq not above the request
        chunk_q -= 1
    ncq = sq // chunk_q
    offs = sk - sq  # decode-style alignment (query block ends at key end)

    qc = q.reshape(b, hq, ncq, chunk_q, hd).transpose(2, 0, 1, 3, 4)

    # static-size KV slice only makes sense for causal SWA (acausal window
    # has no upper key bound); acausal callers fall back to masking
    windowed = window is not None and causal and sk > window + chunk_q
    if windowed:
        kwin = window + chunk_q

    def body(xs):
        qi, i = xs                                   # [B,H,Tq,hd], scalar
        q_pos = offs + i * chunk_q + jnp.arange(chunk_q)
        if windowed:
            start = jnp.clip(offs + i * chunk_q - window + 1, 0, sk - kwin)
            ks = jax.lax.dynamic_slice_in_dim(k, start, kwin, axis=2)
            vs = jax.lax.dynamic_slice_in_dim(v, start, kwin, axis=2)
            k_pos = start + jnp.arange(kwin)
        else:
            ks, vs = k, v
            k_pos = jnp.arange(sk)
        s = jnp.einsum("bhqd,bhkd->bhqk", qi, ks).astype(jnp.float32) * scale
        mask = jnp.ones((chunk_q, k_pos.shape[0]), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vs.dtype), vs)

    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)

    def scan_body(_, xs):
        return None, body(xs)

    _, out = jax.lax.scan(scan_body, None,
                          (qc, jnp.arange(ncq, dtype=jnp.int32)))
    # [ncq, B, H, Tq, hd] -> [B, H, Sq, hd]
    out = out.transpose(1, 2, 0, 3, 4).reshape(b, hq, sq, hd)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,       # [B, Hq, 1, hd]
    k_cache: jax.Array, # [B, Hkv, S, hd]
    v_cache: jax.Array, # [B, Hkv, S, hd]
    valid_len: jax.Array | int,  # scalar or [B]: #valid cache entries
) -> jax.Array:
    """Single-token decode: one matvec over the cache (memory-bound)."""
    b, hq, _, hd = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, hd)
    scores = jnp.einsum("bhgd,bhkd->bhgk", qg, k_cache).astype(jnp.float32)
    scores *= hd ** -0.5
    pos = jnp.arange(s)
    vl = jnp.asarray(valid_len)
    vl = vl[:, None, None, None] if vl.ndim else vl
    scores = jnp.where(pos < vl, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache)
    return out.reshape(b, hq, 1, hd)


# ---------------------------------------------------------------------------
# Block-level apply (self / cross, train / decode)
# ---------------------------------------------------------------------------


def self_attention(
    x: jax.Array,              # [B, S, d]
    p: dict,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    positions: jax.Array,      # [B, S]
    causal: bool = True,
    window: int | None = None,
    cache: tuple | None = None,   # (k_cache, v_cache, index) for decode
    chunk_q: int = 512,
    shard=lambda x, s: x,
):
    """Returns (out [B,S,d], new_cache or None)."""
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(b, s, n_kv, head_dim)
    v = (x @ p["wv"]).reshape(b, s, n_kv, head_dim)
    q = layers.rotary(q, positions).swapaxes(1, 2)   # [B, H, S, hd]
    k = layers.rotary(k, positions).swapaxes(1, 2)
    v = v.swapaxes(1, 2)
    # NOTE (hillclimb-3, refuted hypothesis): pinning K/V sequence-
    # replicated here to hoist the per-chunk gathers made every term WORSE
    # (X 6.6->9.4s) — XLA's auto-chosen head x seq (4x4) attention layout
    # beats forced KV replication.  Kept as a no-op plumbing point; see
    # EXPERIMENTS.md §Perf iteration log.

    if cache is not None:
        k_cache, v_cache, idx = cache
        slot = idx % k_cache.shape[2]   # ring buffer (identity if cache full-length)
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=2)
        valid = jnp.minimum(idx + 1, k_cache.shape[2])
        out = decode_attention(q, k_cache, v_cache, valid)
        new_cache = (k_cache, v_cache, idx + 1)
    else:
        out = chunked_attention(q, k, v, causal=causal, window=window,
                                chunk_q=chunk_q)
        new_cache = (k, v)   # post-rotary K/V — prefill cache material

    out = out.swapaxes(1, 2).reshape(b, s, n_heads * head_dim)
    return out @ p["wo"], new_cache


def cross_attention(
    x: jax.Array,          # [B, S, d]     text stream
    memory: jax.Array,     # [B, M, d]     vision/audio embeddings
    p: dict,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
):
    b, s, _ = x.shape
    m = memory.shape[1]
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim).swapaxes(1, 2)
    k = (memory @ p["wk"]).reshape(b, m, n_kv, head_dim).swapaxes(1, 2)
    v = (memory @ p["wv"]).reshape(b, m, n_kv, head_dim).swapaxes(1, 2)
    out = chunked_attention(q, k, v, causal=False, chunk_q=min(512, s))
    out = out.swapaxes(1, 2).reshape(b, s, n_heads * head_dim)
    return out @ p["wo"]
