"""Mixture-of-Experts FFN: top-k routing, sort-based capacity dispatch, EP.

Dispatch avoids the O(T*E*C) one-hot tensor: tokens are argsorted by expert
id and scattered into a fixed [E*C, d] buffer (C = capacity per expert), the
expert matmuls run as one grouped einsum [E, C, d] x [E, d, ff], and results
are combined back with the routing weights.

Distribution (the hillclimb-1 result — see EXPERIMENTS.md §Perf): the
dispatch runs *group-locally*.  Tokens are split into ``groups`` batches
aligned with the data shards; each group routes/scatters its own tokens into
its own [E, C_g, d] buffer with NO cross-device traffic, and the only
collectives are the sharding-constraint boundaries around the expert einsum
(batch-sharded dispatch buffer -> expert-sharded compute), which XLA lowers
to all-to-alls of exactly the dispatched activations.  The naive global
scatter instead lowered to per-layer all-reduces of the full [T*k, d]
buffer — 35x more wire bytes (measured).

Over-capacity tokens are dropped per group (per-shard capacity, the standard
large-scale semantics); the auxiliary load-balancing loss is returned for
the trainer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import param as pm

NOSHARD = lambda x, spec: x  # noqa: E731


def init_moe(key, d_model: int, d_ff: int, n_experts: int, dtype,
             *, fsdp: bool = False):
    ks = jax.random.split(key, 4)
    fa = ("data", "pod") if fsdp else None  # pod joins FSDP on multi-pod meshes
    params = {
        "router": pm.normal(ks[0], (d_model, n_experts), d_model ** -0.5,
                            jnp.float32),
        "w_up": pm.normal(ks[1], (n_experts, d_model, d_ff), d_model ** -0.5, dtype),
        "w_gate": pm.normal(ks[2], (n_experts, d_model, d_ff), d_model ** -0.5, dtype),
        "w_down": pm.normal(ks[3], (n_experts, d_ff, d_model), d_ff ** -0.5, dtype),
    }
    specs = {
        "router": P(None, None),
        "w_up": P("model", fa, None),
        "w_gate": P("model", fa, None),
        "w_down": P("model", None, fa),
    }
    return params, specs


def _capacity(tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(cf * tokens * top_k / n_experts)
    return max(8, -(-c // 8) * 8)


def _route_group(x, router, *, top_k, capacity, n_experts):
    """Group-local routing decisions (pure index math, no data movement).

    Returns (sel = (perm_token, dest, weight, keep), aux)."""
    t, d = x.shape
    logits = x.astype(jnp.float32) @ router                  # [Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, top_k)               # [Tg, k]
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # aux load-balance loss: E * sum_e (frac_tokens_e * mean_prob_e)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(ids[:, 0], n_experts, dtype=jnp.float32),
                  axis=0)
    aux = n_experts * jnp.sum(me * ce)

    flat_ids = ids.reshape(-1)                               # [Tg*k]
    flat_w = weights.reshape(-1)
    token_of = jnp.repeat(jnp.arange(t), top_k)

    order = jnp.argsort(flat_ids)                            # stable
    sorted_ids = flat_ids[order]
    counts = jax.ops.segment_sum(jnp.ones_like(sorted_ids), sorted_ids,
                                 num_segments=n_experts)
    start = jnp.cumsum(counts) - counts                      # [E]
    pos_in_expert = jnp.arange(t * top_k) - start[sorted_ids]
    keep = pos_in_expert < capacity
    dest = sorted_ids * capacity + jnp.where(keep, pos_in_expert, 0)
    dest = jnp.where(keep, dest, n_experts * capacity)       # drop bucket

    sel = (token_of[order], dest, flat_w[order], keep)
    return sel, aux


def _dispatch_group(x, token_ord, dest, *, rows):
    """Group-local data movement: gather tokens in expert order and scatter
    into the fixed dispatch buffer.  dest indices are unique within a group
    by construction (position-in-expert), which lets XLA emit a plain
    permuting scatter instead of a combining one."""
    xs = x[token_ord]                                        # [Tg*k, d]
    buf = jnp.zeros((rows, x.shape[1]), x.dtype)
    return buf.at[dest].set(xs, unique_indices=True, mode="drop"), xs


def _combine_group(down_flat, sel, t, d):
    """down_flat [E*C+1, d] (with drop row); scatter back to tokens."""
    token_ord, dest, w_ord, keep = sel
    out_sorted = down_flat[dest] * (w_ord * keep)[:, None].astype(
        down_flat.dtype)
    return jnp.zeros((t, d), down_flat.dtype).at[token_ord].add(out_sorted)


def moe_ffn(
    x: jax.Array,            # [T, d]  flattened tokens
    p: dict,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    groups: int = 1,
    model_shards: int = 1,
    shard=NOSHARD,
) -> tuple[jax.Array, jax.Array]:
    """Returns (out [T, d], aux_loss scalar).

    Two EP strategies are auto-selected (hillclimb-1, EXPERIMENTS.md §Perf):

      gathered  when dispatched activations outweigh expert weights (olmoe:
                40x) AND the weights fit HBM when replicated: tokens split
                into one group per *device* (batch x model shards), every
                group routes/dispatches/computes locally against all-gathered
                expert weights (grads reduce-scatter back).  Zero activation
                movement; wire ~ 2 x expert-weight bytes per layer.

      a2a       otherwise (kimi: 34 GB of experts per layer cannot be
                replicated): one group per batch shard; dispatch buffers
                cross to the expert shards and back — wire ~ 2 x dispatched
                activation bytes per layer.
    """
    t, d = x.shape
    n_experts = p["router"].shape[1]
    d_ff = p["w_up"].shape[-1]
    e_bytes = 3 * n_experts * d * d_ff * p["w_up"].dtype.itemsize
    t_bytes = t * top_k * d * x.dtype.itemsize * capacity_factor
    gathered = e_bytes <= (1 << 30) and t_bytes > 4 * e_bytes

    g = max(1, groups * (model_shards if gathered else 1))
    while t % g:
        g -= 1
    tg = t // g
    capacity = _capacity(tg, top_k, n_experts, capacity_factor)
    group_spec = ("batch", "model") if gathered else "batch"

    xg = shard(x.reshape(g, tg, d), P(group_spec, None, None))
    sel, aux = jax.vmap(
        lambda xl: _route_group(xl, p["router"], top_k=top_k,
                                capacity=capacity, n_experts=n_experts))(xg)
    rows = n_experts * capacity + 1
    buf, _ = jax.vmap(
        lambda xl, to, de: _dispatch_group(xl, to, de, rows=rows))(
        xg, sel[0], sel[1])
    he = buf[:, : n_experts * capacity].reshape(g, n_experts, capacity, d)

    if gathered:
        he = shard(he, P(group_spec, None, None, None))
        w_up = shard(p["w_up"], P(None, None, None))
        w_gate = shard(p["w_gate"], P(None, None, None))
        w_down = shard(p["w_down"], P(None, None, None))
    else:
        he = shard(he, P(group_spec, "model", None, None))
        w_up, w_gate, w_down = p["w_up"], p["w_gate"], p["w_down"]

    up = jnp.einsum("gecd,edf->gecf", he, w_up)
    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", he, w_gate))
    down = jnp.einsum("gecf,efd->gecd", up * gate, w_down)
    # return path: outputs live with their token-owner shards for the
    # combine (an expert-sharded buffer would lower the combine gather to
    # masked all-reduces of the full [Tg*k, d] block)
    down = shard(down, P(group_spec, None, None, None))

    down_flat = down.reshape(g, n_experts * capacity, d)
    down_flat = jnp.concatenate(
        [down_flat, jnp.zeros((g, 1, d), down.dtype)], axis=1)  # drop row
    out = jax.vmap(lambda df, s: _combine_group(df, s, tg, d))(down_flat, sel)
    out = shard(out, P(group_spec, None, None))
    return out.reshape(t, d), jnp.mean(aux)
