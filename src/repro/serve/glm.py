"""GLM scoring service: batched admission over the fused scoring kernel.

The training half of the repo turns the paper's §4/§5 access-path
findings into SGD kernels; this is the inference half the north star
calls "millions of users": a scoring engine for trained GLMs (LR
probabilities, SVM margins) built from three pieces —

* **batched admission** — a bounded FIFO queue; requests accumulate
  until either ``max_batch`` are waiting or the oldest has waited
  ``flush_deadline_s``, then one micro-batch is scored.  Batches are
  always *padded to exactly* ``max_batch`` rows (all-zero filler), so
  every launch has one stable shape and jit never re-traces on traffic
  wobble (the serving analogue of the study runner's vmap-stacked
  grids);
* **the fused scoring kernel** — ``kernels/glm_score``: one launch per
  batch, model pinned in VMEM, ELL gather as one-hot MXU matmuls, the
  task link (LR sigmoid / SVM identity) fused in.  Dispatch goes
  through the standard three-backend registry, so the engine runs
  anywhere the conformance suite does;
* **atomic snapshot hot-swap** — the model is an immutable
  :class:`ModelSnapshot`; ``swap_model`` publishes a new snapshot in a
  single reference assignment, and a flush reads the reference exactly
  once for its whole batch.  Readers therefore never observe a torn
  update: every response is stamped with the one ``model_version`` that
  scored it (the snapshot-read discipline async training needs — see
  ROADMAP "train while serving").

Thread model: any number of producer threads may ``try_admit``/
``submit``; any number of consumer threads may ``flush`` (dequeue is
under the lock, scoring is outside it).  Every path is traced
(``serve.admit`` / ``serve.batch`` / ``serve.score`` spans) and counted
when telemetry is on (docs/OBSERVABILITY.md).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.glm import LINKS
from repro.kernels.glm_score import glm_score
from repro.obs import metrics, trace


@dataclasses.dataclass(frozen=True)
class ModelSnapshot:
    """One immutable published model.  ``w`` is the [d] weight vector;
    ``version`` increases by 1 per ``swap_model``.  ``step`` is the
    producer's progress stamp — a live learner publishes the learner
    step that produced this model (``repro.live.publish``), so staleness
    is measurable per snapshot; None for models with no live producer."""

    task: str
    w: jax.Array
    version: int
    step: int | None = None

    def __post_init__(self):
        if self.task not in LINKS:
            raise ValueError(f"unknown task {self.task!r}; "
                             f"one of {tuple(LINKS)}")


@dataclasses.dataclass(frozen=True)
class ScoreRequest:
    """One request row in padded-ELL form (values zero-padded to the
    engine's ``ell_width``; padded entries carry index 0, value 0)."""

    rid: int
    values: np.ndarray   # [<=K] float
    indices: np.ndarray  # [<=K] int


@dataclasses.dataclass(frozen=True)
class ScoreResponse:
    rid: int
    score: float           # LR: sigmoid probability; SVM: raw margin
    model_version: int     # the ONE snapshot that scored this request
    latency_s: float       # admission -> response wall time


class GLMScoreEngine:
    """Batched scoring over a trained GLM — see the module docstring.

    Parameters
    ----------
    task, w:
        The served model (``swap_model`` replaces it atomically).
    ell_width:
        Fixed ELL row width K.  Shorter request rows are zero-padded up;
        longer rows are rejected at admission (``ValueError``).
    max_batch:
        Rows per scoring launch; also the padded batch shape.
    queue_depth:
        Bound of the admission FIFO; a full queue rejects (``try_admit``
        returns False) instead of buffering unboundedly.
    flush_deadline_s:
        A non-full batch is flushed once its *oldest* request has waited
        this long (``maybe_flush``); ``flush`` ignores the deadline.
    backend / block_rows:
        Forwarded to the ``glm_score`` dispatch (None = auto backend,
        autotuner-consulted row tile).
    clock:
        Injectable monotonic clock (tests pin deadlines without
        sleeping).
    fault_stall_s:
        Chaos/CI hook: every flush sleeps this long before scoring —
        the deadline-stall fault the monitor-smoke job uses to force a
        latency-SLO breach.  0 (the default) is a plain no-op.

    A :class:`repro.obs.monitor.HealthMonitor` attaches via its
    ``attach_engine(engine)`` (sets ``self.monitor``); each flush then
    reports rows, queue depth, fill, and per-request latencies.  With
    no monitor attached the only cost is one ``None`` check per flush.
    """

    def __init__(self, task: str, w, *, ell_width: int,
                 max_batch: int = 32, queue_depth: int = 256,
                 flush_deadline_s: float = 0.005,
                 backend: str | None = None, block_rows: int | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 fault_stall_s: float = 0.0):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1: {max_batch}")
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1: {queue_depth}")
        if ell_width < 1:
            raise ValueError(f"ell_width must be >= 1: {ell_width}")
        self.ell_width = ell_width
        self.max_batch = max_batch
        self.queue_depth = queue_depth
        self.flush_deadline_s = flush_deadline_s
        if fault_stall_s < 0:
            raise ValueError(f"fault_stall_s must be >= 0: {fault_stall_s}")
        self.backend = backend
        self.block_rows = block_rows
        self.fault_stall_s = fault_stall_s
        self.monitor = None
        self._clock = clock
        self._lock = threading.Lock()
        #: FIFO of (request, padded values row, padded indices row, t_admit)
        self._queue: deque = deque()
        self._model = ModelSnapshot(
            task, jnp.asarray(w, jnp.float32).reshape(-1), version=0)

    # -- model hot-swap ------------------------------------------------------

    @property
    def model(self) -> ModelSnapshot:
        """The currently published snapshot (atomic reference read)."""
        return self._model

    def swap_model(self, w, *, task: str | None = None,
                   step: int | None = None) -> ModelSnapshot:
        """Atomically publish a new model; returns the new snapshot.

        In-flight batches keep scoring against the snapshot they read at
        dequeue time — a flush is consistent with exactly one version,
        never a mix.  ``step`` stamps the producer's progress (the live
        learner step that trained this model) onto the snapshot.
        """
        with self._lock:
            old = self._model
            w = jnp.asarray(w, jnp.float32).reshape(-1)
            if w.shape != old.w.shape:
                raise ValueError(
                    f"swap_model shape mismatch: serving d={old.w.shape[0]}, "
                    f"got d={w.shape[0]}")
            snap = ModelSnapshot(task if task is not None else old.task,
                                 w, version=old.version + 1, step=step)
            self._model = snap
        metrics.counter("serve.model_swaps").inc()
        if trace.enabled():
            trace.instant("serve.swap", version=snap.version)
        return snap

    # -- admission -----------------------------------------------------------

    def _pad_row(self, req: ScoreRequest) -> tuple[np.ndarray, np.ndarray]:
        vals = np.asarray(req.values, np.float32).reshape(-1)
        idx = np.asarray(req.indices, np.int32).reshape(-1)
        if vals.shape != idx.shape:
            raise ValueError(
                f"request {req.rid}: values/indices length mismatch "
                f"({vals.shape[0]} vs {idx.shape[0]})")
        if vals.shape[0] > self.ell_width:
            raise ValueError(
                f"request {req.rid}: {vals.shape[0]} nonzeros exceed the "
                f"engine ell_width={self.ell_width}")
        pad = self.ell_width - vals.shape[0]
        if pad:
            vals = np.pad(vals, (0, pad))
            idx = np.pad(idx, (0, pad))
        return vals, idx

    def try_admit(self, req: ScoreRequest) -> bool:
        """Enqueue one request; False when the bounded FIFO is full.

        Malformed rows (width over ``ell_width``, ragged values/indices)
        raise — they could never score — while backpressure is a clean
        False so producers can retry/shed.
        """
        row = self._pad_row(req)
        with trace.span("serve.admit", rid=req.rid):
            with self._lock:
                full = len(self._queue) >= self.queue_depth
                if not full:
                    self._queue.append((req, *row, self._clock()))
        if full:
            metrics.counter("serve.rejected").inc()
            if self.monitor is not None:
                self.monitor.on_reject()
            return False
        metrics.counter("serve.admitted").inc()
        return True

    def submit(self, req: ScoreRequest, *, spin_s: float = 1e-4) -> None:
        """Blocking admit: spins (releasing the lock) until space frees.

        Only sensible when some other thread drains via ``flush``.
        """
        while not self.try_admit(req):
            time.sleep(spin_s)

    def __len__(self) -> int:
        return len(self._queue)

    # -- scoring -------------------------------------------------------------

    def _dequeue(self, limit: int) -> list:
        with self._lock:
            n = min(limit, len(self._queue))
            return [self._queue.popleft() for _ in range(n)]

    def flush(self) -> list[ScoreResponse]:
        """Score up to ``max_batch`` queued requests (FIFO) now.

        The batch is padded to exactly ``max_batch`` all-zero rows so the
        jitted launch sees one stable shape; filler scores are dropped.
        Returns one response per dequeued request, in admission order,
        all stamped with the single snapshot that scored them.
        """
        entries = self._dequeue(self.max_batch)
        if not entries:
            return []
        snap = self._model       # ONE atomic snapshot read per batch
        n = len(entries)
        vals = np.zeros((self.max_batch, self.ell_width), np.float32)
        idx = np.zeros((self.max_batch, self.ell_width), np.int32)
        for i, (_, v, ix, _) in enumerate(entries):
            vals[i] = v
            idx[i] = ix
        with trace.span("serve.batch", rows=n, padded=self.max_batch,
                        version=snap.version):
            if self.fault_stall_s:
                time.sleep(self.fault_stall_s)      # injected deadline stall
            with trace.span("serve.score", backend=self.backend or "auto"):
                scores = glm_score(
                    snap.task, snap.w, jnp.asarray(vals), jnp.asarray(idx),
                    block_rows=self.block_rows, backend=self.backend)
                scores = np.asarray(
                    jax.block_until_ready(scores), np.float32)
        t1 = self._clock()
        metrics.counter("serve.scored").inc(n)
        metrics.counter("serve.batches").inc()
        responses = [
            ScoreResponse(req.rid, float(scores[i]), snap.version,
                          max(0.0, t1 - t_admit))
            for i, (req, _, _, t_admit) in enumerate(entries)
        ]
        if self.monitor is not None:
            self.monitor.on_flush(
                n=n, padded=self.max_batch, queue_depth=len(self._queue),
                latencies=[r.latency_s for r in responses])
        return responses

    def maybe_flush(self) -> list[ScoreResponse]:
        """Flush only when a batch is *due*: ``max_batch`` rows waiting,
        or the oldest request older than ``flush_deadline_s``."""
        with self._lock:
            if not self._queue:
                return []
            full = len(self._queue) >= self.max_batch
            overdue = (self._clock() - self._queue[0][3]
                       >= self.flush_deadline_s)
        if not (full or overdue):
            return []
        return self.flush()

    def drain(self, *, max_flushes: int = 10_000) -> list[ScoreResponse]:
        """Flush until the queue is empty; responses in admission order."""
        out: list[ScoreResponse] = []
        for _ in range(max_flushes):
            batch = self.flush()
            if not batch:
                break
            out.extend(batch)
        return out
