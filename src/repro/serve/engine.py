"""Batched serving engine: continuous-batching-lite over fixed decode slots.

The engine owns a fixed batch of decode slots (the decode_32k shape: 128
slots, 32k cache).  Requests are admitted into free slots after a prefill
step; every engine tick runs one fused decode step for all slots; finished
sequences free their slot.  Greedy or temperature sampling.

This mirrors production continuous batching minus speculative decoding:
per-slot state is (cache slice, position, done).  Since caches are stacked
per-layer and slot-indexed on the batch axis, admission writes one batch row
— a dynamic_update_slice per cache leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import decode as decode_mod
from repro.nn import transformer
from repro.nn.transformer import ArchConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S0] token ids
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def _batch_axis_of(cache_leaf_spec):  # caches: batch axis position varies
    return None


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_len: int = 256, temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.cache, _ = decode_mod.init_cache(cfg, slots, max_len)
        self.pos = np.zeros(slots, np.int32)        # next write index
        self.live: list[Request | None] = [None] * slots
        self.last_tok = np.zeros(slots, np.int32)

        def step(params, cache, tokens, idx):
            logits, cache = decode_mod.decode_step(
                params, cfg, cache, {"tokens": tokens}, idx)
            return logits, cache

        self._step = jax.jit(step)

    # -- admission ---------------------------------------------------------

    def try_admit(self, req: Request) -> bool:
        try:
            slot = self.live.index(None)
        except ValueError:
            return False
        # prefill the prompt token-by-token through the decode path (slot
        # isolation; bulk prefill would use transformer.forward(mode=
        # "prefill") on a dedicated prefill batch in a disaggregated setup)
        logits = None
        for t, tok in enumerate(req.prompt):
            tokens = jnp.asarray(self.last_tok.reshape(-1, 1))
            tokens = tokens.at[slot, 0].set(int(tok))
            logits, self.cache = self._step(
                self.params, self.cache, tokens, jnp.int32(self.pos[slot]))
            self.pos[slot] += 1
        self.live[slot] = req
        if logits is not None:
            self.last_tok[slot] = int(jnp.argmax(logits[slot]))
            req.out.append(int(self.last_tok[slot]))
        # empty prompt: nothing to prefill, so there is no prompt-conditioned
        # logit yet — the first token comes from the next tick (the slot
        # decodes from its current last_tok, 0 at engine start = BOS-like)
        return True

    # -- one decode tick for the whole batch --------------------------------

    def tick(self):
        if all(r is None for r in self.live):
            return
        tokens = jnp.asarray(self.last_tok.reshape(-1, 1))
        idx = jnp.int32(int(self.pos.max()))        # slots share the tick idx
        logits, self.cache = self._step(self.params, self.cache, tokens, idx)
        if self.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            nxt = jax.random.categorical(sub, logits / self.temperature,
                                         axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = np.asarray(nxt, np.int32)
        for s, req in enumerate(self.live):
            if req is None:
                continue
            req.out.append(int(nxt[s]))
            self.last_tok[s] = nxt[s]
            self.pos[s] += 1
            if len(req.out) >= req.max_new or self.pos[s] >= self.max_len - 1:
                req.done = True
                self.live[s] = None          # free the slot

    def run(self, requests: list[Request], max_ticks: int = 1000):
        """Drive to completion; returns the finished requests."""
        pending = list(requests)
        for _ in range(max_ticks):
            while pending and self.try_admit(pending[0]):
                pending.pop(0)
            if not pending and all(r is None for r in self.live):
                break
            self.tick()
        return [r for r in requests if r.done]
