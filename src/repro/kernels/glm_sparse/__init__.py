from repro.kernels.glm_sparse.ops import ell_glm_grad  # noqa: F401
