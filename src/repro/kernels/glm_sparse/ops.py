"""Public wrapper for the ELL sparse GLM gradient — registry-dispatched.

The Pallas one-hot-MXU flavors carry a capability budget (one-hot FLOPs
grow with d; the margin scratch burns N*4 bytes of VMEM), so very wide /
very tall problems auto-route to the ``reference`` XLA gather/segment-sum
flavor — the sparse analogue of the paper's per-dataset optimal-
configuration finding (Table 6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common, tune
from repro.kernels.glm_sparse import kernel as K
from repro.kernels.glm_sparse import ref as R

# Budget heuristics for the Pallas path.
_MAX_D_PALLAS = 32_768      # one-hot FLOPs grow with d
_MAX_N_PALLAS = 131_072     # margin scratch = N * 4 bytes of VMEM


def pallas_path_ok(n: int, d: int) -> bool:
    return d <= _MAX_D_PALLAS and n <= _MAX_N_PALLAS


_PALLAS_CAPS = common.Caps(
    sparse=True,
    check=lambda info: pallas_path_ok(info.get("n", 0), info.get("d", 0)),
)


@functools.partial(
    jax.jit, static_argnames=("task", "block_rows", "d_block", "interpret")
)
def _pallas(task, w, values, indices, y, *, block_rows, d_block, interpret):
    n, kk = values.shape
    d = w.shape[0]
    d_pad = common.padded(d, d_block)
    n_pad = common.padded(n, block_rows)
    vp = common.pad_to(values.astype(jnp.float32), 0, n_pad)
    ip = common.pad_to(indices.astype(jnp.int32), 0, n_pad)
    yp = common.pad_to(y.astype(jnp.float32).reshape(n, 1), 0, n_pad, value=1.0)
    wp = common.pad_to(w.astype(jnp.float32).reshape(d, 1), 0, d_pad)
    g = K.ell_glm_grad_pallas(
        task, wp, vp, ip, yp,
        block_rows=block_rows, d_block=d_block, interpret=interpret,
    )
    return g[:d, 0]


@common.register_kernel("glm_sparse", common.PALLAS_TPU, caps=_PALLAS_CAPS)
def _glm_sparse_tpu(task, w, values, indices, y, *, block_rows=8, d_block=512):
    return _pallas(task, w, values, indices, y, block_rows=block_rows,
                   d_block=d_block, interpret=False)


@common.register_kernel("glm_sparse", common.PALLAS_INTERPRET, caps=_PALLAS_CAPS)
def _glm_sparse_interpret(task, w, values, indices, y, *, block_rows=8,
                          d_block=512):
    return _pallas(task, w, values, indices, y, block_rows=block_rows,
                   d_block=d_block, interpret=True)


@common.register_kernel(
    "glm_sparse", common.REFERENCE, caps=common.Caps(dtypes=None, sparse=True)
)
@functools.partial(jax.jit, static_argnames=("task", "block_rows", "d_block"))
def _glm_sparse_reference(task, w, values, indices, y, *, block_rows=8,
                          d_block=512):
    del block_rows, d_block
    return R.ell_glm_grad_ref(
        task, w.astype(jnp.float32), values.astype(jnp.float32), indices,
        y.astype(jnp.float32),
    )


def ell_glm_grad(
    task: str,
    w: jax.Array,        # [d]
    values: jax.Array,   # [N, K]
    indices: jax.Array,  # [N, K] int32
    y: jax.Array,        # [N]
    *,
    block_rows: int | None = None,
    d_block: int | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
    force_path: str | None = None,   # legacy: "pallas" | "xla" | None (auto)
) -> jax.Array:
    """ELL sparse GLM gradient via the best available backend.

    Unpinned ``block_rows``/``d_block`` consult the autotuner cache
    (:mod:`repro.kernels.tune`); with no cached winner the historical
    defaults (8, 512) apply.
    """
    n, d = values.shape[0], w.shape[0]
    if force_path == "xla":
        backend = backend or common.REFERENCE
    elif force_path == "pallas" and backend is None:
        # legacy forcing bypassed the budget; interpret= picks the flavor
        use_interp = (not common.on_tpu()) if interpret is None else interpret
        backend = common.PALLAS_INTERPRET if use_interp else common.PALLAS_TPU
    elif backend is None and interpret is not None and pallas_path_ok(n, d):
        # legacy interpret= chose the Pallas mode but never overrode the
        # budget: over-budget problems still take the reference path
        backend = common.PALLAS_INTERPRET if interpret else common.PALLAS_TPU
    info = {"dtype": jnp.result_type(values).name, "sparse": True,
            "n": n, "d": d}
    b = common.resolve_backend("glm_sparse", backend=backend, info=info)
    if block_rows is None and d_block is None:
        run = None
        if tune.timeable(w, values, indices, y):
            run = lambda **cfg: common.dispatch(  # noqa: E731
                "glm_sparse", task, w, values, indices, y, backend=b, **cfg)
        cfg = tune.consult("glm_sparse", b, info, run)
        block_rows = cfg.get("block_rows")
        d_block = cfg.get("d_block")
    return common.dispatch(
        "glm_sparse", task, w, values, indices, y,
        block_rows=block_rows if block_rows is not None else 8,
        d_block=d_block if d_block is not None else 512,
        backend=b, info=info,
    )
