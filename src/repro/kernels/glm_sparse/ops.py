"""Jitted public wrapper for the ELL sparse GLM gradient.

Picks between the Pallas one-hot-MXU kernel (moderate d, bounded N) and the
XLA gather/segment-sum path (ref) based on a VMEM/FLOP budget — the sparse
analogue of the paper's per-dataset optimal-configuration finding (Table 6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.glm_sparse import kernel as K
from repro.kernels.glm_sparse import ref as R

# Budget heuristics for choosing the Pallas path.
_MAX_D_PALLAS = 32_768      # one-hot FLOPs grow with d
_MAX_N_PALLAS = 131_072     # margin scratch = N * 4 bytes of VMEM


def pallas_path_ok(n: int, d: int) -> bool:
    return d <= _MAX_D_PALLAS and n <= _MAX_N_PALLAS


@functools.partial(
    jax.jit,
    static_argnames=("task", "block_rows", "d_block", "interpret", "force_path"),
)
def ell_glm_grad(
    task: str,
    w: jax.Array,        # [d]
    values: jax.Array,   # [N, K]
    indices: jax.Array,  # [N, K] int32
    y: jax.Array,        # [N]
    *,
    block_rows: int = 8,
    d_block: int = 512,
    interpret: bool | None = None,
    force_path: str | None = None,   # "pallas" | "xla" | None (auto)
) -> jax.Array:
    interpret = common.resolve_interpret(interpret)
    n, kk = values.shape
    d = w.shape[0]

    path = force_path or ("pallas" if pallas_path_ok(n, d) else "xla")
    if path == "xla":
        return R.ell_glm_grad_ref(task, w, values, indices, y)

    d_pad = common.padded(d, d_block)
    n_pad = common.padded(n, block_rows)
    vp = common.pad_to(values.astype(jnp.float32), 0, n_pad)
    ip = common.pad_to(indices.astype(jnp.int32), 0, n_pad)
    yp = common.pad_to(y.astype(jnp.float32).reshape(n, 1), 0, n_pad, value=1.0)
    wp = common.pad_to(w.astype(jnp.float32).reshape(d, 1), 0, d_pad)
    g = K.ell_glm_grad_pallas(
        task, wp, vp, ip, yp,
        block_rows=block_rows, d_block=d_block, interpret=interpret,
    )
    return g[:d, 0]
