"""ELL sparse GLM gradient Pallas kernel — gather/scatter as one-hot MXU ops.

The paper pads CSR to a fixed width so the GPU col-major path gets coalesced
access (Section 5.2.1).  The TPU analogue goes further: there is no efficient
per-lane random gather into VMEM, but the MXU turns gather/scatter over a
*bounded feature block* into dense matmuls against a one-hot matrix:

    gather :  w[idx]        ==  onehot(idx, Db) @ w_block
    scatter:  g[idx] += c   ==  g_block += onehot(idx, Db)^T @ c

The kernel runs a two-phase sequential grid (phase, d-block, row-tile):

    phase 0:  accumulate margins m_i = x_i . w across d-blocks into a
              VMEM-resident margin buffer (whole shard);
    phase 1:  pull_i = f'(y_i m_i); scatter-accumulate vals * pull into the
              gradient d-block (row tiles are contiguous per d-block, so the
              output block accumulates in VMEM and flushes exactly once).

Everything is fixed-shape; the only data-dependent values are the indices,
which never leave the integer compare feeding the one-hot.  Padded entries
(value 0) contribute 0 to both phases, so no explicit masking is needed
beyond clamping out-of-block indices to 0 with value 0.  This trades
O(N*K*d) MXU FLOPs for zero irregular memory traffic — profitable exactly
when d is moderate (w8a / real-sim scale).  For very wide models (news) the
XLA gather/segment-sum path (ref.py) is the production path; ops.py picks
automatically based on a VMEM/FLOP budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def _pull(task, margins, y):
    if task == "lr":
        return -y * jax.nn.sigmoid(-margins)
    return -y * (margins < 1.0).astype(margins.dtype)


def _kernel(task, d_block, vals_ref, idx_ref, y_ref, w_ref, g_ref, mar_s):
    phase = pl.program_id(0)
    j = pl.program_id(1)          # d block   (output block: slow axis)
    i = pl.program_id(2)          # row tile  (contiguous revisits per block)

    vals = vals_ref[...]          # [TB, K]
    idx = idx_ref[...]            # [TB, K] int32 (global feature ids)
    tb, kk = vals.shape

    lo = j * d_block
    local = idx - lo              # [TB, K]
    in_block = (local >= 0) & (local < d_block)
    local = jnp.where(in_block, local, 0)
    sel = jnp.where(in_block, vals, 0.0)       # masked values (0 => no-op)

    # one-hot [TB*K, Db] — the MXU-side gather/scatter operand
    iota_d = jax.lax.broadcasted_iota(jnp.int32, (tb * kk, d_block), 1)
    onehot = (local.reshape(tb * kk, 1) == iota_d).astype(jnp.float32)

    @pl.when(phase == 0)
    def _phase0():
        @pl.when(j == 0)
        def _():
            mar_s[pl.ds(i * tb, tb), :] = jnp.zeros((tb, 1), jnp.float32)

        w_blk = w_ref[...]                     # [Db, 1]
        wg = jnp.dot(onehot, w_blk, preferred_element_type=jnp.float32)
        partial = jnp.sum(sel * wg.reshape(tb, kk), axis=1, keepdims=True)
        mar_s[pl.ds(i * tb, tb), :] += partial

    @pl.when(phase == 1)
    def _phase1():
        @pl.when(i == 0)
        def _():
            g_ref[...] = jnp.zeros_like(g_ref)

        y = y_ref[...]                         # [TB, 1]
        m = y * mar_s[pl.ds(i * tb, tb), :]    # full margins (phase 0 done)
        pull = _pull(task, m, y)               # [TB, 1]
        contrib = (sel * pull).reshape(tb * kk, 1)
        g_ref[...] += jax.lax.dot_general(     # onehot^T @ contrib -> [Db, 1]
            onehot, contrib, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


def ell_glm_grad_pallas(
    task: str,
    w: jax.Array,        # [d_pad, 1]
    values: jax.Array,   # [N_pad, K]
    indices: jax.Array,  # [N_pad, K] int32
    y: jax.Array,        # [N_pad, 1]
    *,
    block_rows: int,
    d_block: int,
    interpret: bool,
) -> jax.Array:
    n_pad, kk = values.shape
    d_pad = w.shape[0]
    assert n_pad % block_rows == 0 and d_pad % d_block == 0
    grid = (2, d_pad // d_block, n_pad // block_rows)
    body = functools.partial(_kernel, task, d_block)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, kk), lambda p, j, i: (i, 0)),  # values
            pl.BlockSpec((block_rows, kk), lambda p, j, i: (i, 0)),  # indices
            pl.BlockSpec((block_rows, 1), lambda p, j, i: (i, 0)),   # y
            pl.BlockSpec((d_block, 1), lambda p, j, i: (j, 0)),      # w block
        ],
        out_specs=pl.BlockSpec((d_block, 1), lambda p, j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((d_pad, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n_pad, 1), jnp.float32)],  # margins
        compiler_params=common.tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(values, indices, y, w)
