"""Pure-jnp oracle for the ELL sparse GLM gradient kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pull(task, margins, y):
    if task == "lr":
        return -y * jax.nn.sigmoid(-margins)
    return -y * (margins < 1.0).astype(margins.dtype)


def ell_glm_grad_ref(
    task: str,
    w: jax.Array,        # [d]
    values: jax.Array,   # [N, K]  zero-padded
    indices: jax.Array,  # [N, K]  int32 (0-padded; padded values are 0)
    y: jax.Array,        # [N]
) -> jax.Array:
    """Sum GLM gradient on ELL data via gather + segment-sum (XLA path)."""
    d = w.shape[0]
    wg = jnp.take(w, indices, axis=0)            # [N, K]
    margins = y * jnp.sum(values * wg, axis=1)   # [N]
    pull = _pull(task, margins, y)
    contrib = values * pull[:, None]             # [N, K]
    return jax.ops.segment_sum(
        contrib.reshape(-1), indices.reshape(-1), num_segments=d
    )
