"""Fused sparse (ELL) incremental-SGD epoch Pallas kernel.

The dense ``glm_sgd`` kernel fuses gradient + update into one launch with
the model pinned in VMEM scratch (Section 5's Hogwild-kernel analogue).
This is its sparse sibling: the per-step example tile is a padded-ELL
``(values, indices)`` pair, and — like ``glm_sparse`` — the gather and
scatter against the VMEM-resident model become dense one-hot MXU matmuls:

    grid step k:  load ELL tile vals_k/idx_k [MB, K] (HBM->VMEM stream)
                  onehot  = (idx_k == iota_d)                 [MB*K, d]
                  margins = y_k * rowsum(vals_k * onehot@w)   (MXU)
                  w_vmem -= (alpha/MB) * onehot^T (vals*pull) (MXU + VPU)

One launch = one epoch = N/MB model updates with zero HBM traffic for
the model.  The one-hot spans the *full* padded feature axis (no
d-blocking): the model must stay live across steps, so ops.py budgets
``MB * K * d_pad`` against VMEM and routes over-budget problems to the
reference oracle.  Padded ELL entries (value 0) contribute 0 to both the
margin and the scatter, so no masking is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def _pull(task, margins, y):
    if task == "lr":
        return -y * jax.nn.sigmoid(-margins)
    return -y * (margins < 1.0).astype(margins.dtype)


def _kernel(task, scale, vals_ref, idx_ref, y_ref, w0_ref, out_ref, w_s):
    @pl.when(pl.program_id(0) == 0)
    def _():
        w_s[...] = w0_ref[...]

    vals = vals_ref[...]              # [MB, K]
    idx = idx_ref[...]                # [MB, K] int32 (global feature ids)
    y = y_ref[...]                    # [MB, 1]
    mb, kk = vals.shape
    d_pad = w_s.shape[0]

    # one-hot [MB*K, d_pad] — gather AND scatter operand for the MXU
    iota_d = jax.lax.broadcasted_iota(jnp.int32, (mb * kk, d_pad), 1)
    onehot = (idx.reshape(mb * kk, 1) == iota_d).astype(jnp.float32)

    w = w_s[...]                      # [d_pad, 1]
    wg = jnp.dot(onehot, w, preferred_element_type=jnp.float32)  # [MB*K, 1]
    margins = y * jnp.sum(vals * wg.reshape(mb, kk), axis=1, keepdims=True)
    pull = _pull(task, margins, y)    # [MB, 1]
    contrib = (vals * pull).reshape(mb * kk, 1)
    g = jax.lax.dot_general(          # onehot^T @ contrib -> [d_pad, 1]
        onehot, contrib, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    w_s[...] = w - scale * g

    @pl.when(pl.program_id(0) == pl.num_programs(0) - 1)
    def _():
        out_ref[...] = w_s[...]


def ell_sgd_pallas(
    task: str,
    w0: jax.Array,       # [d_pad, 1]
    values: jax.Array,   # [N, K]
    indices: jax.Array,  # [N, K] int32
    y: jax.Array,        # [N, 1]
    *,
    step: float,
    micro_batch: int,
    interpret: bool,
) -> jax.Array:
    n, kk = values.shape
    d_pad = w0.shape[0]
    assert n % micro_batch == 0, (n, micro_batch)
    grid = (n // micro_batch,)
    body = functools.partial(_kernel, task, step / micro_batch)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((micro_batch, kk), lambda i: (i, 0)),
            pl.BlockSpec((micro_batch, kk), lambda i: (i, 0)),
            pl.BlockSpec((micro_batch, 1), lambda i: (i, 0)),
            pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d_pad, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d_pad, 1), jnp.float32)],
        compiler_params=common.tpu_compiler_params(
            dimension_semantics=("arbitrary",),  # sequential: state carried
        ),
        interpret=interpret,
    )(values, indices, y, w0)
