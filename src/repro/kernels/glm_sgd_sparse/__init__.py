from repro.kernels.glm_sgd_sparse.ops import ell_sgd_epoch  # noqa: F401
