"""Pure-jnp oracle for the fused sparse (ELL) incremental-SGD epoch."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pull(task, margins, y):
    if task == "lr":
        return -y * jax.nn.sigmoid(-margins)
    return -y * (margins < 1.0).astype(margins.dtype)


def ell_sgd_epoch_ref(
    task: str,
    w: jax.Array,        # [d]
    values: jax.Array,   # [N, K]  zero-padded ELL
    indices: jax.Array,  # [N, K]  int32 (0-padded; padded values are 0)
    y: jax.Array,        # [N]
    step: float,
    batch: int,
) -> jax.Array:
    """Sequential mini-batch SGD pass on ELL data (gather + segment-sum).

    batch=1 is exact incremental SGD.  Any ``n`` is accepted: full
    batches are scanned, a non-divisible remainder is applied as one
    final smaller batch at ``step/|tail|`` (mean-gradient rule) — the
    same ragged-tail semantics as the dense ``glm_sgd`` oracle.
    """
    d = w.shape[0]

    def update(w, vk, ik, yk):
        wg = jnp.take(w, ik, axis=0)                 # [B, K]
        margins = yk * jnp.sum(vk * wg, axis=1)      # [B]
        pull = _pull(task, margins, yk)
        contrib = vk * pull[:, None]                 # [B, K]
        g = jax.ops.segment_sum(
            contrib.reshape(-1), ik.reshape(-1), num_segments=d
        )
        return w - (step / vk.shape[0]) * g

    n, k = values.shape
    n_full = (n // batch) * batch
    if n_full:
        vb = values[:n_full].reshape(n_full // batch, batch, k)
        ib = indices[:n_full].reshape(n_full // batch, batch, k)
        yb = y[:n_full].reshape(n_full // batch, batch)
        w, _ = jax.lax.scan(
            lambda w, t: (update(w, *t), None), w, (vb, ib, yb)
        )
    if n_full < n:
        w = update(w, values[n_full:], indices[n_full:], y[n_full:])
    return w
