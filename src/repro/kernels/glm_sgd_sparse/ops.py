"""Public wrapper for the fused sparse-SGD epoch — registry-dispatched.

The ``reference`` flavor is the gather/segment-sum lax.scan oracle; the
Pallas flavors run one launch per epoch with the model pinned in VMEM and
gather/scatter lowered to one-hot MXU matmuls (kernel.py).

Two capability gates route problems the kernel cannot shape to the
oracle: ``n % micro_batch == 0`` (the epoch is a fixed grid of tiles) and
a one-hot VMEM budget ``MB * K * d_pad`` (the one-hot spans the full
padded feature axis because the model never leaves VMEM).  Forcing a
Pallas flavor past the divisibility gate raises ``ValueError``.  When the
caller does not pin ``micro_batch``, the per-device autotuner cache
(:mod:`repro.kernels.tune`) is consulted before the built-in default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common, tune
from repro.kernels.glm_sgd_sparse import kernel as K
from repro.kernels.glm_sgd_sparse import ref as R

#: built-in micro-batch when neither the caller nor the tuner pins one
DEFAULT_MICRO_BATCH = 8

#: the one-hot operand [MB*K, d_pad] fp32 must stay a small VMEM tenant
#: next to the pinned model and the streamed ELL tiles
_MAX_ONEHOT_BYTES = 4 * 2 ** 20


def onehot_budget_ok(d: int, k: int, micro_batch: int) -> bool:
    d_pad = common.padded(max(d, 1), common.LANE)
    return micro_batch * k * d_pad * 4 <= _MAX_ONEHOT_BYTES


def _check_divisible(n: int, micro_batch: int) -> None:
    if micro_batch < 1 or n % micro_batch:
        raise ValueError(
            f"glm_sgd_sparse Pallas flavors need n % micro_batch == 0, got "
            f"n={n}, micro_batch={micro_batch}; drop the explicit backend "
            f"to fall through to 'reference' (ragged-tail oracle) or pick "
            f"a divisor of n")


def _caps_check(info: dict) -> bool:
    n, mb = info.get("n"), info.get("micro_batch")
    if n is not None and mb is not None and (mb < 1 or n % mb):
        return False
    d, k = info.get("d"), info.get("k")
    if d is not None and k is not None and mb is not None:
        return onehot_budget_ok(d, k, mb)
    return True


_PALLAS_CAPS = common.Caps(sparse=True, check=_caps_check)


@functools.partial(
    jax.jit, static_argnames=("task", "step", "micro_batch", "interpret")
)
def _pallas(task, w, values, indices, y, *, step, micro_batch, interpret):
    """One fused sparse SGD epoch; model stays in VMEM throughout.

    N must be divisible by ``micro_batch`` (checked, ValueError); d is
    padded to the 128-lane tile internally.
    """
    n, _ = values.shape
    d = w.shape[0]
    _check_divisible(n, micro_batch)
    d_pad = common.padded(d, common.LANE)
    vp = values.astype(jnp.float32)
    ip = indices.astype(jnp.int32)
    yp = y.astype(jnp.float32).reshape(n, 1)
    wp = common.pad_to(w.astype(jnp.float32).reshape(d, 1), 0, d_pad)
    w_out = K.ell_sgd_pallas(
        task, wp, vp, ip, yp, step=step, micro_batch=micro_batch,
        interpret=interpret,
    )
    return w_out[:d, 0]


@common.register_kernel("glm_sgd_sparse", common.PALLAS_TPU, caps=_PALLAS_CAPS)
def _ell_sgd_tpu(task, w, values, indices, y, *, step,
                 micro_batch=DEFAULT_MICRO_BATCH):
    return _pallas(task, w, values, indices, y, step=step,
                   micro_batch=micro_batch, interpret=False)


@common.register_kernel("glm_sgd_sparse", common.PALLAS_INTERPRET,
                        caps=_PALLAS_CAPS)
def _ell_sgd_interpret(task, w, values, indices, y, *, step,
                       micro_batch=DEFAULT_MICRO_BATCH):
    return _pallas(task, w, values, indices, y, step=step,
                   micro_batch=micro_batch, interpret=True)


@common.register_kernel("glm_sgd_sparse", common.REFERENCE,
                        caps=common.Caps(dtypes=None, sparse=True))
@functools.partial(jax.jit, static_argnames=("task", "step", "micro_batch"))
def _ell_sgd_reference(task, w, values, indices, y, *, step,
                       micro_batch=DEFAULT_MICRO_BATCH):
    return R.ell_sgd_epoch_ref(
        task, w.astype(jnp.float32), values.astype(jnp.float32),
        indices.astype(jnp.int32), y.astype(jnp.float32), step, micro_batch,
    )


def ell_sgd_epoch(
    task: str,
    w: jax.Array,        # [d]
    values: jax.Array,   # [N, K]  zero-padded ELL
    indices: jax.Array,  # [N, K]  int32
    y: jax.Array,        # [N]
    *,
    step: float,
    micro_batch: int | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """One mini-batch SGD epoch on ELL data via the best available backend.

    ``micro_batch=None`` consults the autotuner cache for this
    (backend, device, shape-class) before falling back to
    ``DEFAULT_MICRO_BATCH``.
    """
    n, kk = values.shape
    d = w.shape[0]
    info = {"dtype": jnp.result_type(values).name, "sparse": True,
            "n": n, "d": d, "k": kk}
    if micro_batch is None:
        b0 = common.resolve_backend("glm_sgd_sparse", backend=backend,
                                    interpret=interpret, info=info)
        run = None
        if tune.timeable(w, values, indices, y):
            run = lambda **cfg: common.dispatch(  # noqa: E731
                "glm_sgd_sparse", task, w, values, indices, y, step=step,
                backend=b0, **cfg)
        micro_batch = tune.consult("glm_sgd_sparse", b0, info, run) \
            .get("micro_batch", DEFAULT_MICRO_BATCH)
    info["micro_batch"] = micro_batch
    return common.dispatch(
        "glm_sgd_sparse", task, w, values, indices, y, step=step,
        micro_batch=micro_batch, backend=backend, interpret=interpret,
        info=info,
    )
