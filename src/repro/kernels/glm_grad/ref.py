"""Pure-jnp oracle for the fused GLM gradient kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def glm_grad_ref(task: str, w: jax.Array, X: jax.Array, y: jax.Array) -> jax.Array:
    """Sum gradient of the GLM loss over the batch: X^T pull(y * Xw)."""
    margins = y * (X @ w)
    if task == "lr":
        pull = -y * jax.nn.sigmoid(-margins)
    elif task == "svm":
        pull = -y * (margins < 1.0).astype(X.dtype)
    else:
        raise ValueError(task)
    return X.T @ pull
