"""Fused GLM gradient Pallas kernel — the paper's data-access-path axis on TPU.

One kernel fuses the whole gradient pipeline (margin matvec -> pull -> X^T
accumulate), replacing the paper's chain of blocking ViennaCL primitives.
The model ``w`` is resident in VMEM for the entire grid; example tiles
stream HBM->VMEM once.  Two physical layouts realize the paper's row- vs
col-major access paths:

* ``row``:  X stored ``[N, d]``; a tile ``[TB, d]`` puts the *feature* axis on
  the 128-lane minor dimension — the margin matvec contracts along lanes
  (MXU-friendly) but the X^T-pull accumulation needs a transposed operand.
* ``col``:  X stored ``[d, N]`` (transposed up front, like the paper's
  materialized transpose); a tile ``[d, TB]`` puts the *example* axis on
  lanes — the gradient accumulation ``Xc @ pull`` is lane-aligned
  ("coalesced") while the margin matvec is the transposed one.

The roofline consequences of this choice are measured in
benchmarks/fig8_access_path.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def _pull(task: str, margins: jax.Array, y: jax.Array) -> jax.Array:
    if task == "lr":
        return -y * jax.nn.sigmoid(-margins)
    return -y * (margins < 1.0).astype(margins.dtype)


def _kernel_row(task, x_ref, y_ref, w_ref, g_ref):
    X = x_ref[...]            # [TB, d]
    w = w_ref[...]            # [d, 1]
    y = y_ref[...]            # [TB, 1]
    margins = y * jnp.dot(X, w, preferred_element_type=jnp.float32)
    pull = _pull(task, margins, y)

    @pl.when(pl.program_id(0) == 0)
    def _():
        g_ref[...] = jnp.zeros_like(g_ref)

    g_ref[...] += jax.lax.dot_general(
        X, pull, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # X^T @ pull : contract example axis


def _kernel_col(task, xc_ref, y_ref, w_ref, g_ref):
    Xc = xc_ref[...]          # [d, TB]  (example axis on lanes)
    w = w_ref[...]            # [d, 1]
    y = y_ref[...]            # [TB, 1]
    # margins = (Xc^T w): contract the feature axis (sublanes)
    margins = y * jax.lax.dot_general(
        Xc, w, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    pull = _pull(task, margins, y)

    @pl.when(pl.program_id(0) == 0)
    def _():
        g_ref[...] = jnp.zeros_like(g_ref)

    g_ref[...] += jnp.dot(Xc, pull, preferred_element_type=jnp.float32)


def glm_grad_pallas(
    task: str,
    w: jax.Array,     # [d_pad, 1]
    X: jax.Array,     # [N_pad, d_pad] (row) or [d_pad, N_pad] (col)
    y: jax.Array,     # [N_pad, 1]
    *,
    layout: str,
    block_rows: int,
    interpret: bool,
) -> jax.Array:
    if layout == "row":
        n_pad, d_pad = X.shape
        x_spec = pl.BlockSpec((block_rows, d_pad), lambda i: (i, 0))
        body = functools.partial(_kernel_row, task)
    else:
        d_pad, n_pad = X.shape
        x_spec = pl.BlockSpec((d_pad, block_rows), lambda i: (0, i))
        body = functools.partial(_kernel_col, task)
    grid = (n_pad // block_rows,)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            x_spec,
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),   # y
            pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),        # w (resident)
        ],
        out_specs=pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),  # g accumulator
        out_shape=jax.ShapeDtypeStruct((d_pad, 1), jnp.float32),
        compiler_params=common.tpu_compiler_params(
            dimension_semantics=("arbitrary",),  # revisited output block
        ),
        interpret=interpret,
    )(X, y, w)
