"""Jitted public wrapper for the fused GLM gradient kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.glm_grad import kernel as K


@functools.partial(
    jax.jit, static_argnames=("task", "layout", "block_rows", "interpret")
)
def glm_grad(
    task: str,
    w: jax.Array,   # [d]
    X: jax.Array,   # [N, d]
    y: jax.Array,   # [N]
    *,
    layout: str = "row",
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Sum GLM gradient via the fused Pallas kernel.  Returns [d].

    Pads d to the 128-lane tile and N to the row-block size (zero example
    rows contribute zero gradient, so padding is exact).  ``layout='col'``
    materializes the transpose up front — the paper's col-major access path.
    """
    interpret = common.resolve_interpret(interpret)
    n, d = X.shape
    d_pad = common.padded(d, common.LANE)
    if block_rows is None:
        block_rows = max(common.SUBLANE, min(512, common.padded(n, common.SUBLANE)))
    n_pad = common.padded(n, block_rows)

    Xp = common.pad_to(common.pad_to(X.astype(jnp.float32), 1, d_pad), 0, n_pad)
    yp = common.pad_to(y.astype(jnp.float32).reshape(n, 1), 0, n_pad, value=1.0)
    wp = common.pad_to(w.astype(jnp.float32).reshape(d, 1), 0, d_pad)

    if layout == "col":
        Xp = Xp.T  # materialized transpose (paper: col-major path)

    g = K.glm_grad_pallas(
        task, wp, Xp, yp, layout=layout, block_rows=block_rows, interpret=interpret
    )
    return g[:d, 0]
