"""Public wrapper for the fused GLM gradient kernel — registry-dispatched.

Three registered flavors (paper: "every primitive in two flavors"):
``pallas-tpu`` / ``pallas-interpret`` run kernel.py; ``reference`` runs
the ref.py oracle.  All flavors cast inputs to fp32 (the kernels
accumulate in fp32), so bf16 inputs agree across backends to fp32
round-off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common, tune
from repro.kernels.glm_grad import kernel as K
from repro.kernels.glm_grad import ref as R


@functools.partial(
    jax.jit, static_argnames=("task", "layout", "block_rows", "interpret")
)
def _pallas(task, w, X, y, *, layout, block_rows, interpret):
    """Pad to TPU tiles and run the Pallas kernel.  Returns [d] fp32.

    Pads d to the 128-lane tile and N to the row-block size (zero example
    rows contribute zero gradient, so padding is exact).  ``layout='col'``
    materializes the transpose up front — the paper's col-major access path.
    """
    n, d = X.shape
    d_pad = common.padded(d, common.LANE)
    if block_rows is None:
        block_rows = max(common.SUBLANE, min(512, common.padded(n, common.SUBLANE)))
    n_pad = common.padded(n, block_rows)

    Xp = common.pad_to(common.pad_to(X.astype(jnp.float32), 1, d_pad), 0, n_pad)
    yp = common.pad_to(y.astype(jnp.float32).reshape(n, 1), 0, n_pad, value=1.0)
    wp = common.pad_to(w.astype(jnp.float32).reshape(d, 1), 0, d_pad)

    if layout == "col":
        Xp = Xp.T  # materialized transpose (paper: col-major path)

    g = K.glm_grad_pallas(
        task, wp, Xp, yp, layout=layout, block_rows=block_rows, interpret=interpret
    )
    return g[:d, 0]


@common.register_kernel("glm_grad", common.PALLAS_TPU)
def _glm_grad_tpu(task, w, X, y, *, layout="row", block_rows=None):
    return _pallas(task, w, X, y, layout=layout, block_rows=block_rows,
                   interpret=False)


@common.register_kernel("glm_grad", common.PALLAS_INTERPRET)
def _glm_grad_interpret(task, w, X, y, *, layout="row", block_rows=None):
    return _pallas(task, w, X, y, layout=layout, block_rows=block_rows,
                   interpret=True)


@common.register_kernel("glm_grad", common.REFERENCE, caps=common.Caps(dtypes=None))
@functools.partial(jax.jit, static_argnames=("task", "layout", "block_rows"))
def _glm_grad_reference(task, w, X, y, *, layout="row", block_rows=None):
    del layout, block_rows  # access path is a kernel-layout concept
    return R.glm_grad_ref(task, w.astype(jnp.float32), X.astype(jnp.float32),
                          y.astype(jnp.float32))


def glm_grad(
    task: str,
    w: jax.Array,   # [d]
    X: jax.Array,   # [N, d]
    y: jax.Array,   # [N]
    *,
    layout: str = "row",
    block_rows: int | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Sum GLM gradient via the best available backend.  Returns [d] fp32.

    ``block_rows=None`` consults the autotuner cache
    (:mod:`repro.kernels.tune`) for this (backend, device, shape-class);
    with no cached winner the kernel's built-in heuristic applies.
    """
    info = {"dtype": jnp.result_type(X).name, "n": X.shape[0], "d": X.shape[1]}
    b = common.resolve_backend("glm_grad", backend=backend,
                               interpret=interpret, info=info)
    if block_rows is None:
        run = None
        if tune.timeable(w, X, y):
            run = lambda **cfg: common.dispatch(  # noqa: E731
                "glm_grad", task, w, X, y, layout=layout, backend=b, **cfg)
        block_rows = tune.consult("glm_grad", b, info, run).get("block_rows")
    return common.dispatch(
        "glm_grad", task, w, X, y, layout=layout, block_rows=block_rows,
        backend=b, info=info,
    )
