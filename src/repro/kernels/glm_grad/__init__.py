from repro.kernels.glm_grad.ops import glm_grad  # noqa: F401
