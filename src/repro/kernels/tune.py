"""Per-device block/grid autotuner for the Pallas kernel families.

The paper's §5.2 finding is that kernel speed is a *configuration*
problem — the winning block/batch geometry depends on the dataset shape
and the hardware.  The repo's four-plus kernel families, however, ran at
whatever block sizes they were born with.  This module closes that gap
with the same content-hash cache idiom the study subsystem uses for
trials:

* every kernel family declares its tunable parameters and a candidate
  grid (:data:`TUNABLES`) — e.g. ``block_rows`` for ``glm_grad``,
  ``micro_batch`` for the fused SGD epochs, ``(block_q, block_k)`` for
  ``flash_attn``;
* :func:`tune` sweeps the candidates with ``median_time`` and persists
  the winner (plus the full candidate timing table) on disk, keyed by
  ``(schema, kernel, backend, device kind, shape-class, dtype)`` —
  nearby shapes share a power-of-two **shape class** so one sweep serves
  the whole bucket;
* each family's ``ops.py`` consults :func:`consult` when the caller does
  *not* pin a block size: a cached winner is applied transparently; on a
  cache miss the call falls back to the family's built-in default unless
  ``REPRO_KERNEL_AUTOTUNE=1`` is set, in which case the sweep runs right
  there (never under a jit trace — tracers cannot be timed) and is
  cached for every later call.

Cache location: ``$REPRO_TUNE_DIR``, default
``~/.cache/repro-sgd-tune``.  Invalidation is by construction: a new
device kind, backend, shape class, dtype, or a :data:`SCHEMA` bump
hashes to a different key; deleting the directory forces a full re-tune.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from pathlib import Path
from typing import Any, Callable

import jax

from repro.kernels import common
from repro.obs import metrics, trace
from repro.utils.timing import median_time

#: bump when record semantics change in a way that invalidates cached winners
SCHEMA = 1

ENV_TUNE_DIR = "REPRO_TUNE_DIR"
#: "1" -> a dispatch-time cache miss triggers the sweep (off by default:
#: unpinned call sites then simply use the family's built-in defaults)
ENV_AUTOTUNE = "REPRO_KERNEL_AUTOTUNE"


def canonical_json(obj) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def _digest(obj) -> str:
    return hashlib.sha256(canonical_json(obj).encode()).hexdigest()[:16]


def tune_dir() -> Path:
    root = os.environ.get(ENV_TUNE_DIR)
    if root:
        return Path(root)
    return Path.home() / ".cache" / "repro-sgd-tune"


def device_kind() -> str:
    """Normalized accelerator model string — part of every cache key."""
    try:
        kind = jax.devices()[0].device_kind
    except Exception:  # pragma: no cover - no backend at all
        kind = "unknown"
    return str(kind).strip().lower().replace(" ", "-")


def shape_class(info: dict[str, Any]) -> dict[str, Any]:
    """Bucket every integer call-info field to the next power of two.

    ``{"n": 96, "d": 50, "dtype": "float32"}`` and ``{"n": 128, "d": 64,
    ...}`` land in the same class, so one tuning sweep serves all nearby
    shapes instead of re-timing per exact size.  Non-integers (dtype
    strings, flags) pass through unchanged; bools are kept as bools.
    """
    out: dict[str, Any] = {}
    for k in sorted(info):
        v = info[k]
        if isinstance(v, bool) or not isinstance(v, int):
            out[k] = v
        elif v <= 0:
            out[k] = 0
        else:
            out[k] = 1 << max(0, v - 1).bit_length()
    return out


def timeable(*arrays) -> bool:
    """True when the arrays are concrete (a sweep can actually be timed).

    Call sites inside a jit trace see tracers; tuning there is
    impossible, so ``consult`` degrades to a pure cache lookup.
    """
    return not any(isinstance(a, jax.core.Tracer) for a in arrays)


# ---------------------------------------------------------------------------
# Candidate grids
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Tunable:
    """Tunable parameters of one kernel family + its candidate grid."""

    params: tuple[str, ...]
    candidates: Callable[[dict], tuple[dict, ...]]


def _row_block_candidates(info: dict) -> tuple[dict, ...]:
    """Row-tile sizes for kernels that pad N up to the block."""
    n_pad = common.padded(max(int(info.get("n", 0)), 1), common.SUBLANE)
    blocks = sorted({b for b in (8, 32, 128, 512) if b <= n_pad} | {n_pad})
    return tuple({"block_rows": b} for b in blocks)


def _micro_batch_candidates(info: dict) -> tuple[dict, ...]:
    """Micro-batch sizes that divide N (the fused-epoch divisibility cap)."""
    n = int(info.get("n", 0))
    mbs = [b for b in (1, 2, 4, 8, 16, 32, 64, 128) if n and n % b == 0]
    return tuple({"micro_batch": b} for b in (mbs or [1]))


def _sparse_candidates(info: dict) -> tuple[dict, ...]:
    n_pad = common.padded(max(int(info.get("n", 0)), 1), common.SUBLANE)
    rows = [b for b in (8, 16, 32) if b <= n_pad] or [8]
    d = max(int(info.get("d", 0)), 1)
    dbs = [db for db in (128, 256, 512) if db <= common.padded(d, 128)]
    return tuple({"block_rows": b, "d_block": db}
                 for b in rows for db in (dbs or [128]))


def _attn_candidates(info: dict) -> tuple[dict, ...]:
    def blocks(size):
        out = [b for b in (8, 16, 32, 64, 128, 256)
               if size and size % b == 0]
        return out or ([size] if size and size % common.SUBLANE == 0 else [])

    bqs = blocks(int(info.get("seq_q", 0)))
    bks = blocks(int(info.get("seq_k", 0)))
    return tuple({"block_q": bq, "block_k": bk} for bq in bqs for bk in bks)


TUNABLES: dict[str, Tunable] = {
    "glm_grad": Tunable(("block_rows",), _row_block_candidates),
    "glm_score": Tunable(("block_rows",), _row_block_candidates),
    "glm_sgd": Tunable(("micro_batch",), _micro_batch_candidates),
    "glm_sgd_sparse": Tunable(("micro_batch",), _micro_batch_candidates),
    "glm_sparse": Tunable(("block_rows", "d_block"), _sparse_candidates),
    "flash_attn": Tunable(("block_q", "block_k"), _attn_candidates),
}


# ---------------------------------------------------------------------------
# On-disk winner cache
# ---------------------------------------------------------------------------


class TuneCache:
    """Content-addressed winner cache: ``<root>/<key>.json`` (study idiom)."""

    def __init__(self, root: str | Path | None = None):
        self.root = Path(root) if root is not None else tune_dir()

    def key(self, kernel: str, backend: str, info: dict) -> str:
        return _digest({
            "schema": SCHEMA,
            "kernel": kernel,
            "backend": backend,
            "device_kind": device_kind(),
            "shape_class": shape_class(info),
        })

    def get(self, key: str) -> dict | None:
        try:
            with open(self.root / f"{key}.json") as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def put(self, key: str, payload: dict) -> None:
        self.root.mkdir(parents=True, exist_ok=True)
        tmp = self.root / f".{key}.tmp.{os.getpid()}"
        tmp.write_text(canonical_json(payload))
        tmp.replace(self.root / f"{key}.json")  # atomic on POSIX


# ---------------------------------------------------------------------------
# Tuning + dispatch-time consultation
# ---------------------------------------------------------------------------


def lookup(kernel: str, backend: str, info: dict, *,
           cache: TuneCache | None = None) -> dict | None:
    """The cached winning config for this call class, or None.

    Only parameters the family declares tunable are returned, so a
    stale/foreign record can never inject unexpected kwargs.
    """
    tunable = TUNABLES.get(kernel)
    if tunable is None:
        return None
    cache = cache if cache is not None else TuneCache()
    rec = cache.get(cache.key(kernel, backend, info))
    if rec is None or not isinstance(rec.get("config"), dict):
        metrics.counter(f"kernel.tune_cache.miss.{kernel}").inc()
        return None
    metrics.counter(f"kernel.tune_cache.hit.{kernel}").inc()
    cfg = {k: v for k, v in rec["config"].items() if k in tunable.params}
    return cfg or None


def tune(
    kernel: str,
    backend: str,
    info: dict,
    run: Callable[..., Any],
    *,
    cache: TuneCache | None = None,
    warmup: int = 1,
    iters: int = 3,
    force: bool = False,
) -> dict:
    """Sweep the family's candidate grid and cache the fastest config.

    ``run(**config)`` must execute the kernel once with the candidate
    config and return a jax value (it is timed with device sync).
    Returns the full record::

        {"config": {...winner...},
         "candidates": [{"config": {...}, "wall_s": ...}, ...],
         "kernel": ..., "backend": ..., "device_kind": ...,
         "shape_class": {...}, "schema": SCHEMA}

    A cached record for the same key short-circuits the sweep unless
    ``force=True``.
    """
    if kernel not in TUNABLES:
        raise KeyError(f"no tunable parameters declared for {kernel!r}; "
                       f"known: {tuple(sorted(TUNABLES))}")
    cache = cache if cache is not None else TuneCache()
    key = cache.key(kernel, backend, info)
    if not force:
        rec = cache.get(key)
        if rec is not None:
            return rec

    candidates = TUNABLES[kernel].candidates(info)
    if not candidates:
        raise ValueError(f"no {kernel!r} candidates for call info {info!r}")
    table = []
    with trace.span("kernel.tune", kernel=kernel, backend=backend,
                    candidates=len(candidates)):
        for cfg in candidates:
            wall = median_time(lambda c=cfg: run(**c),
                               warmup=warmup, iters=iters)
            table.append({"config": cfg, "wall_s": wall})
    best = min(table, key=lambda r: r["wall_s"])
    rec = {
        "schema": SCHEMA,
        "kernel": kernel,
        "backend": backend,
        "device_kind": device_kind(),
        "shape_class": shape_class(info),
        "config": best["config"],
        "candidates": table,
    }
    cache.put(key, rec)
    return rec


def consult(kernel: str, backend: str, info: dict,
            run: Callable[..., Any] | None = None, *,
            cache: TuneCache | None = None) -> dict:
    """Config for an unpinned call site: cached winner, tuned, or ``{}``.

    The empty dict means "use the family's built-in default".  A sweep
    runs only when ``REPRO_KERNEL_AUTOTUNE=1`` *and* the caller could
    supply a timeable ``run`` closure (concrete arrays, not a trace).
    """
    cfg = lookup(kernel, backend, info, cache=cache)
    if cfg is not None:
        return cfg
    if run is not None and os.environ.get(ENV_AUTOTUNE) == "1":
        return dict(tune(kernel, backend, info, run, cache=cache)["config"])
    return {}
