"""Public wrapper for the fused ELL scoring kernel — registry-dispatched.

The ``reference`` flavor is the ``lax.scan`` gather/link oracle; the
Pallas flavors score one padded micro-batch per launch with the model
pinned in VMEM and the gather lowered to one-hot MXU matmuls
(kernel.py).  Rows are independent, so — unlike the fused SGD epoch —
there is no divisibility cap: N is zero-padded up to ``block_rows`` and
the filler scores are sliced off.  One capability gate routes problems
the one-hot cannot shape to the oracle: the ``block_rows * K * d_pad``
VMEM budget (the one-hot spans the full padded feature axis because the
model never leaves VMEM).  When the caller does not pin ``block_rows``,
the per-device autotuner cache (:mod:`repro.kernels.tune`) is consulted
before the built-in default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common, tune
from repro.kernels.glm_score import kernel as K
from repro.kernels.glm_score import ref as R

#: built-in row tile when neither the caller nor the tuner pins one
DEFAULT_BLOCK_ROWS = 8

#: the one-hot operand [TB*K, d_pad] fp32 must stay a small VMEM tenant
#: next to the pinned model and the streamed ELL tiles
_MAX_ONEHOT_BYTES = 4 * 2 ** 20


def onehot_budget_ok(d: int, k: int, block_rows: int) -> bool:
    d_pad = common.padded(max(d, 1), common.LANE)
    return block_rows * k * d_pad * 4 <= _MAX_ONEHOT_BYTES


def _caps_check(info: dict) -> bool:
    d, k = info.get("d"), info.get("k")
    if d is not None and k is not None:
        return onehot_budget_ok(d, k, info.get("block_rows",
                                               DEFAULT_BLOCK_ROWS))
    return True


_PALLAS_CAPS = common.Caps(sparse=True, check=_caps_check)


@functools.partial(jax.jit, static_argnames=("task", "block_rows",
                                             "interpret"))
def _pallas(task, w, values, indices, *, block_rows, interpret):
    """One fused scoring launch; model pinned in VMEM throughout.

    N is padded up to ``block_rows`` (filler rows are all-zero, so their
    margin is exactly 0); d is padded to the 128-lane tile internally.
    """
    n, _ = values.shape
    d = w.shape[0]
    n_pad = common.padded(n, block_rows)
    d_pad = common.padded(d, common.LANE)
    vp = common.pad_to(values.astype(jnp.float32), 0, n_pad)
    ip = common.pad_to(indices.astype(jnp.int32), 0, n_pad)
    wp = common.pad_to(w.astype(jnp.float32).reshape(d, 1), 0, d_pad)
    scores = K.glm_score_pallas(
        task, wp, vp, ip, block_rows=block_rows, interpret=interpret,
    )
    return scores[:n, 0]


@common.register_kernel("glm_score", common.PALLAS_TPU, caps=_PALLAS_CAPS)
def _glm_score_tpu(task, w, values, indices, *,
                   block_rows=DEFAULT_BLOCK_ROWS):
    return _pallas(task, w, values, indices, block_rows=block_rows,
                   interpret=False)


@common.register_kernel("glm_score", common.PALLAS_INTERPRET,
                        caps=_PALLAS_CAPS)
def _glm_score_interpret(task, w, values, indices, *,
                         block_rows=DEFAULT_BLOCK_ROWS):
    return _pallas(task, w, values, indices, block_rows=block_rows,
                   interpret=True)


@common.register_kernel(
    "glm_score", common.REFERENCE, caps=common.Caps(dtypes=None, sparse=True)
)
@functools.partial(jax.jit, static_argnames=("task", "block_rows"))
def _glm_score_reference(task, w, values, indices, *,
                         block_rows=DEFAULT_BLOCK_ROWS):
    del block_rows
    return R.glm_score_ref(
        task, w.astype(jnp.float32), values.astype(jnp.float32),
        indices.astype(jnp.int32),
    )


def glm_score(
    task: str,
    w: jax.Array,        # [d]
    values: jax.Array,   # [N, K]  zero-padded ELL
    indices: jax.Array,  # [N, K]  int32
    *,
    block_rows: int | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Served scores for a padded-ELL batch via the best available backend.

    Returns ``[N]`` float32 — LR rows are sigmoid probabilities, SVM rows
    raw decision margins (:data:`repro.core.glm.LINKS`).
    ``block_rows=None`` consults the autotuner cache for this
    (backend, device, shape-class) before falling back to
    ``DEFAULT_BLOCK_ROWS``.
    """
    n, kk = values.shape
    d = w.shape[0]
    info = {"dtype": jnp.result_type(values).name, "sparse": True,
            "n": n, "d": d, "k": kk}
    if block_rows is None:
        b0 = common.resolve_backend("glm_score", backend=backend,
                                    interpret=interpret, info=info)
        run = None
        if tune.timeable(w, values, indices):
            run = lambda **cfg: common.dispatch(  # noqa: E731
                "glm_score", task, w, values, indices, backend=b0, **cfg)
        block_rows = tune.consult("glm_score", b0, info, run) \
            .get("block_rows", DEFAULT_BLOCK_ROWS)
    info["block_rows"] = block_rows
    return common.dispatch(
        "glm_score", task, w, values, indices, block_rows=block_rows,
        backend=backend, interpret=interpret, info=info,
    )
