"""Pure-jnp oracle for the fused ELL GLM scoring kernel.

Scoring is the inference half of the paper's sparse access-path story:
one margin ``m_i = x_i . w`` per request row, pushed through the task's
link (:data:`repro.core.glm.LINKS` — LR sigmoid probability, SVM raw
margin).  The oracle is a ``lax.scan`` over rows — the sequential
semantics every dispatch flavor must match: gather the touched model
coordinates, dot against the ELL values, link.  Padded ELL entries
(value 0) contribute exactly zero to the margin by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.glm import LINKS


def glm_score_ref(
    task: str,
    w: jax.Array,        # [d]
    values: jax.Array,   # [N, K]  zero-padded ELL
    indices: jax.Array,  # [N, K]  int32 (0-padded; padded values are 0)
) -> jax.Array:
    """Per-row served scores on ELL data (scan oracle, XLA path)."""
    link = LINKS[task]

    def body(_, row):
        vals_i, idx_i = row
        margin = jnp.sum(vals_i * jnp.take(w, idx_i, axis=0))
        return None, margin

    _, margins = lax.scan(body, None, (values, indices))
    return link(margins)
