from repro.kernels.glm_score.ops import glm_score  # noqa: F401
