"""Fused sparse (ELL) GLM scoring Pallas kernel — inference sibling of
``glm_sgd_sparse``.

One launch scores a whole padded micro-batch: the model is pinned in
VMEM across the row-tile grid (BlockSpec index map is constant, so the
pipeline never re-streams it), and — like every sparse family here —
the per-row gather ``w[idx]`` lowers to a dense one-hot MXU matmul over
the full padded feature axis:

    grid step i:  load ELL tile vals_i/idx_i [TB, K]  (HBM->VMEM stream)
                  onehot  = (idx_i == iota_d)                  [TB*K, d]
                  margins = rowsum(vals_i * onehot @ w)        (MXU)
                  out_i   = link(margins)                      (VPU)

The link (LR sigmoid / SVM identity) is fused into the launch, so a
scoring batch is exactly one kernel — the serving-path analogue of the
paper's coalesced sparse model access (§5.2.1).  Padded ELL entries
(value 0 at index 0) contribute 0 to the margin, and padded *rows*
(admission-queue filler) are entirely zero, so their margin is exactly
0 and the engine just drops their scores; no masking is needed.  The
one-hot spans the full padded feature axis, so ops.py budgets
``TB * K * d_pad`` bytes against VMEM and routes over-budget problems
to the reference oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl

from repro.kernels import common


def _link(task, margins):
    if task == "lr":
        return jax.nn.sigmoid(margins)
    return margins


def _kernel(task, vals_ref, idx_ref, w_ref, out_ref):
    vals = vals_ref[...]              # [TB, K]
    idx = idx_ref[...]                # [TB, K] int32 (global feature ids)
    tb, kk = vals.shape
    d_pad = w_ref.shape[0]

    # one-hot [TB*K, d_pad] — the MXU-side gather operand
    iota_d = jax.lax.broadcasted_iota(jnp.int32, (tb * kk, d_pad), 1)
    onehot = (idx.reshape(tb * kk, 1) == iota_d).astype(jnp.float32)

    w = w_ref[...]                    # [d_pad, 1] (VMEM-pinned)
    wg = jnp.dot(onehot, w, preferred_element_type=jnp.float32)  # [TB*K, 1]
    margins = jnp.sum(vals * wg.reshape(tb, kk), axis=1, keepdims=True)
    out_ref[...] = _link(task, margins)


def glm_score_pallas(
    task: str,
    w: jax.Array,        # [d_pad, 1]
    values: jax.Array,   # [N_pad, K]
    indices: jax.Array,  # [N_pad, K] int32
    *,
    block_rows: int,
    interpret: bool,
) -> jax.Array:
    n_pad, kk = values.shape
    d_pad = w.shape[0]
    assert n_pad % block_rows == 0, (n_pad, block_rows)
    grid = (n_pad // block_rows,)
    body = functools.partial(_kernel, task)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, kk), lambda i: (i, 0)),  # values
            pl.BlockSpec((block_rows, kk), lambda i: (i, 0)),  # indices
            pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),        # w (pinned)
        ],
        out_specs=pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        compiler_params=common.tpu_compiler_params(
            dimension_semantics=("parallel",),  # rows are independent
        ),
        interpret=interpret,
    )(values, indices, w)
