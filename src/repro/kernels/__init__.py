"""Kernel layer: Pallas TPU kernels behind a backend dispatch registry.

Each kernel family is a ``kernel.py`` (Pallas body) / ``ops.py`` (public
wrapper + backend registration) / ``ref.py`` (pure-jnp oracle) triple.
Importing this package registers all families; ``common.dispatch`` then
routes each call to ``pallas-tpu`` / ``pallas-interpret`` / ``reference``
(see common.py for the selection rules and the ``REPRO_KERNEL_BACKEND``
override).  DESIGN.md §3 documents the layer; the conformance suite is
tests/test_kernel_conformance.py.
"""
from repro.kernels import common  # noqa: F401  (must precede family imports)
from repro.kernels.common import (  # noqa: F401
    PALLAS_INTERPRET,
    PALLAS_TPU,
    REFERENCE,
    available_backends,
    backends_for,
    dispatch,
    register_kernel,
    registered_kernels,
    resolve_backend,
)
from repro.kernels.flash_attn import flash_attention  # noqa: F401
from repro.kernels.glm_grad import glm_grad  # noqa: F401
from repro.kernels.glm_score import glm_score  # noqa: F401
from repro.kernels.glm_sgd import glm_sgd_epoch  # noqa: F401
from repro.kernels.glm_sgd_sparse import ell_sgd_epoch  # noqa: F401
from repro.kernels.glm_sparse import ell_glm_grad  # noqa: F401
