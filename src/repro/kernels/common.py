"""Shared kernel infrastructure: backend dispatch registry, Pallas
version-compat shims, and tiling helpers.

The paper implements every SGD primitive "in two flavors" — CPU routines
and highly-parallel GPU kernels — and picks per dataset/hardware.  The
analogue here is a three-backend registry per kernel family:

* ``pallas-tpu``        compiled Pallas (Mosaic) — the TPU runtime path;
* ``pallas-interpret``  the same Pallas kernel run by the interpreter —
                        bit-for-bit kernel logic, runs anywhere (CPU CI);
* ``reference``         the pure-jnp oracle (ref.py) — XLA-compiled,
                        the correctness ground truth and the fallback
                        when capability flags rule the Pallas path out.

Selection order (``resolve_backend``):

1. explicit call-site forcing: a ``backend=`` argument, or the legacy
   ``interpret=`` / ``force_path=`` flags;
2. the ``REPRO_KERNEL_BACKEND`` environment variable (global override —
   e.g. ``REPRO_KERNEL_BACKEND=reference`` to take Pallas entirely out
   of the picture when bisecting a numerics issue);
3. auto: the first backend of ``pallas-tpu`` → ``pallas-interpret`` →
   ``reference`` that is available on this host AND whose capability
   flags accept the call (dtype, sparsity, shape budgets).

Explicit and env overrides bypass the *capability* heuristics (forcing
is on you) but still fail fast on hard unavailability: ``pallas-tpu``
cannot lower off-TPU and raises a clear error instead of a Mosaic
backtrace.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.obs import metrics, trace

LANE = 128      # TPU minor-dim tile (VREG lanes / MXU edge)
SUBLANE = 8     # fp32 second-minor tile

# ---------------------------------------------------------------------------
# Pallas version-compat shim
# ---------------------------------------------------------------------------


def tpu_compiler_params(**kwargs):
    """Construct TPU compiler params across the Pallas API rename.

    jax <= 0.4.x exposes ``pltpu.TPUCompilerParams``; newer releases
    renamed it to ``pltpu.CompilerParams``.  Every kernel goes through
    this shim so the drift is absorbed in exactly one place.
    """
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None
    )
    if cls is None:
        raise RuntimeError(
            "this Pallas exposes neither CompilerParams nor TPUCompilerParams;"
            " jax >= 0.4.30 is required (see pyproject.toml)")
    return cls(**kwargs)


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

PALLAS_TPU = "pallas-tpu"
PALLAS_INTERPRET = "pallas-interpret"
REFERENCE = "reference"

#: auto-selection preference, best first
BACKEND_ORDER = (PALLAS_TPU, PALLAS_INTERPRET, REFERENCE)

ENV_BACKEND = "REPRO_KERNEL_BACKEND"


@dataclasses.dataclass(frozen=True)
class Caps:
    """Capability flags of one kernel implementation.

    ``None`` means unconstrained.  ``check`` is a free-form predicate on
    the call-info dict for budgets that don't fit a named flag (e.g. the
    glm_sparse one-hot VMEM/FLOP budget).
    """

    dtypes: tuple[str, ...] | None = ("float32", "bfloat16")
    sparse: bool = False                      # consumes ELL sparse operands
    head_dim_multiple: int | None = None      # flash-attn lane constraint
    check: Callable[[dict], bool] | None = None

    def supports(self, info: dict[str, Any]) -> bool:
        dt = info.get("dtype")
        if self.dtypes is not None and dt is not None and dt not in self.dtypes:
            return False
        if info.get("sparse") and not self.sparse:
            return False
        hd = info.get("head_dim")
        if (self.head_dim_multiple and hd is not None
                and hd % self.head_dim_multiple != 0):
            return False
        if self.check is not None and not self.check(info):
            return False
        return True


@dataclasses.dataclass(frozen=True)
class KernelImpl:
    kernel: str
    backend: str
    fn: Callable
    caps: Caps


_REGISTRY: dict[str, dict[str, KernelImpl]] = {}


def register_kernel(kernel: str, backend: str, *, caps: Caps | None = None):
    """Decorator: register ``fn`` as the ``backend`` flavor of ``kernel``.

    All flavors of one kernel must share a call signature; the dispatch
    layer forwards arguments verbatim.
    """
    if backend not in BACKEND_ORDER:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKEND_ORDER}")

    def deco(fn):
        _REGISTRY.setdefault(kernel, {})[backend] = KernelImpl(
            kernel, backend, fn, caps or Caps()
        )
        return fn

    return deco


def registered_kernels() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def backends_for(kernel: str) -> tuple[str, ...]:
    """Registered backends of ``kernel``, in preference order."""
    impls = _REGISTRY.get(kernel, {})
    return tuple(b for b in BACKEND_ORDER if b in impls)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _host_available(backend: str) -> bool:
    """Hard availability: can this backend run on the current host at all?"""
    return backend != PALLAS_TPU or on_tpu()


def available_backends(kernel: str, info: dict | None = None) -> tuple[str, ...]:
    """Backends of ``kernel`` runnable on this host (and, when ``info`` is
    given, whose capability flags accept the call) — what the conformance
    suite parametrizes over."""
    out = []
    for b in backends_for(kernel):
        impl = _REGISTRY[kernel][b]
        if not _host_available(b):
            continue
        if info is not None and not impl.caps.supports(info):
            continue
        out.append(b)
    return tuple(out)


def resolve_backend(
    kernel: str,
    *,
    backend: str | None = None,
    interpret: bool | None = None,
    info: dict | None = None,
) -> str:
    """Pick the backend for one call.  See module docstring for the order.

    ``interpret`` is the legacy flag the pre-registry wrappers exposed:
    True → ``pallas-interpret``, False → ``pallas-tpu``, None → auto.
    Like ``backend``, it is call-site-explicit and beats the env var.
    """
    impls = _REGISTRY.get(kernel)
    if not impls:
        raise KeyError(f"no kernel registered under {kernel!r}; "
                       f"known: {registered_kernels()}")

    forced = backend
    if forced is None and interpret is not None:
        forced = PALLAS_INTERPRET if interpret else PALLAS_TPU
    if forced is None:
        forced = os.environ.get(ENV_BACKEND) or None
    if forced is not None:
        if forced not in impls:
            raise ValueError(
                f"backend {forced!r} not registered for {kernel!r}; "
                f"registered: {backends_for(kernel)}")
        if not _host_available(forced):
            raise RuntimeError(
                f"backend {forced!r} for {kernel!r} needs a TPU host "
                f"(jax.default_backend()={jax.default_backend()!r}); "
                f"available here: {available_backends(kernel)}")
        metrics.counter(f"kernel.backend.{kernel}.{forced}").inc()
        return forced

    info = info or {}
    skipped: list[tuple[str, str]] = []
    for b in backends_for(kernel):
        if not _host_available(b):
            skipped.append((b, "host"))
            continue
        if not impls[b].caps.supports(info):
            skipped.append((b, "caps"))
            continue
        # telemetry: which flavor won, and why better-ranked ones lost.
        # Resolution happens host-side (at jit-trace time for epochs that
        # embed a kernel), so these count *resolutions*, not launches.
        metrics.counter(f"kernel.backend.{kernel}.{b}").inc()
        for sb, reason in skipped:
            metrics.counter(f"kernel.fallback.{kernel}.{sb}.{reason}").inc()
        if skipped and trace.enabled():
            trace.instant("kernel.caps_fallback", kernel=kernel, chosen=b,
                          skipped=[f"{sb}:{r}" for sb, r in skipped])
        return b
    raise RuntimeError(
        f"no backend of {kernel!r} accepts call info {info!r}; "
        f"registered: {backends_for(kernel)}")


def dispatch(
    kernel: str,
    *args,
    backend: str | None = None,
    interpret: bool | None = None,
    info: dict | None = None,
    **kwargs,
):
    """Resolve a backend and invoke the registered implementation."""
    b = resolve_backend(kernel, backend=backend, interpret=interpret, info=info)
    # host-side dispatch span: under jit this times trace/lowering overhead
    # (the launch itself is async); outside jit it times the dispatch call
    with trace.span("kernel.dispatch", kernel=kernel, backend=b):
        return _REGISTRY[kernel][b].fn(*args, **kwargs)


# ---------------------------------------------------------------------------
# Tiling helpers
# ---------------------------------------------------------------------------


def pad_to(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    """Zero-pad ``axis`` up to the next multiple (no-op if aligned)."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


def padded(size: int, multiple: int) -> int:
    return size + ((-size) % multiple)


def pick_block(size: int, preferred: int, multiple: int = 1) -> int:
    """Largest block <= preferred that divides ``size`` and is a multiple of
    ``multiple``.

    When no such block exists the only always-correct fallback is the
    whole extent as a single block — valid only if ``size`` itself is a
    multiple of ``multiple``.  A ``size`` that is not (prime/odd sizes,
    e.g. ``pick_block(6, 128, 8)``) used to fall through to ``size``
    anyway, handing kernels a sublane-misaligned block; now it raises so
    callers either pad the operand first or route to a backend without
    the alignment constraint (the dispatch caps do the latter).
    """
    best = None
    b = multiple
    while b <= min(preferred, size):
        if size % b == 0:
            best = b
        b += multiple
    if best is not None:
        return best
    if size % multiple == 0:
        return size  # single aligned block (may exceed preferred)
    raise ValueError(
        f"no block <= {preferred} divides size {size} at multiple "
        f"{multiple}, and {size} is not itself a multiple of {multiple}; "
        f"pad the operand to {padded(size, multiple)} or pick a backend "
        f"without the alignment constraint")
