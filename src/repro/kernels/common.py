"""Shared helpers for Pallas TPU kernels (padding, interpret detection)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 128      # TPU minor-dim tile (VREG lanes / MXU edge)
SUBLANE = 8     # fp32 second-minor tile


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """interpret=None -> auto: compiled on TPU, interpreted elsewhere (CPU CI)."""
    return (not on_tpu()) if interpret is None else interpret


def pad_to(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    """Zero-pad ``axis`` up to the next multiple (no-op if aligned)."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


def padded(size: int, multiple: int) -> int:
    return size + ((-size) % multiple)


def pick_block(size: int, preferred: int, multiple: int = 1) -> int:
    """Largest block <= preferred that divides ``size`` and is a multiple of
    ``multiple`` — fall back to ``size`` itself (single block)."""
    best = None
    b = multiple
    while b <= min(preferred, size):
        if size % b == 0:
            best = b
        b += multiple
    return best if best is not None else size
