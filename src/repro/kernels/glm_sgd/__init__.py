from repro.kernels.glm_sgd.ops import glm_sgd_epoch  # noqa: F401
