"""Pure-jnp oracle for the fused incremental-SGD epoch kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pull(task, margins, y):
    if task == "lr":
        return -y * jax.nn.sigmoid(-margins)
    return -y * (margins < 1.0).astype(margins.dtype)


def glm_sgd_epoch_ref(
    task: str, w: jax.Array, X: jax.Array, y: jax.Array, step: float, batch: int
) -> jax.Array:
    """Sequential mini-batch SGD pass: w -= (step/batch) * sum-grad per batch.

    batch=1 is exact incremental SGD (paper Algorithm 3)."""
    n, d = X.shape
    assert n % batch == 0
    Xb = X.reshape(n // batch, batch, d)
    yb = y.reshape(n // batch, batch)

    def body(w, xy):
        Xk, yk = xy
        margins = yk * (Xk @ w)
        g = Xk.T @ _pull(task, margins, yk)
        return w - (step / batch) * g, None

    w_out, _ = jax.lax.scan(body, w, (Xb, yb))
    return w_out
