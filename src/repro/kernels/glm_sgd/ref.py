"""Pure-jnp oracle for the fused incremental-SGD epoch kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pull(task, margins, y):
    if task == "lr":
        return -y * jax.nn.sigmoid(-margins)
    return -y * (margins < 1.0).astype(margins.dtype)


def glm_sgd_epoch_ref(
    task: str, w: jax.Array, X: jax.Array, y: jax.Array, step: float, batch: int
) -> jax.Array:
    """Sequential mini-batch SGD pass: w -= (step/|B|) * sum-grad per batch.

    batch=1 is exact incremental SGD (paper Algorithm 3).  Any ``n`` is
    accepted: full batches are scanned, and a non-divisible remainder is
    applied as one final smaller batch (mean-gradient rule, so its scale
    is ``step/|tail|``).  The Pallas flavors require divisibility and
    are routed away by the dispatch caps — this oracle is the fallback.
    """

    def update(w, Xk, yk):
        margins = yk * (Xk @ w)
        g = Xk.T @ _pull(task, margins, yk)
        return w - (step / Xk.shape[0]) * g

    n, d = X.shape
    n_full = (n // batch) * batch
    if n_full:
        Xb = X[:n_full].reshape(n_full // batch, batch, d)
        yb = y[:n_full].reshape(n_full // batch, batch)
        w, _ = jax.lax.scan(lambda w, xy: (update(w, *xy), None), w, (Xb, yb))
    if n_full < n:
        w = update(w, X[n_full:], y[n_full:])
    return w
