"""Public wrapper for the fused incremental-SGD epoch — registry-dispatched.

The ``reference`` flavor is the sequential lax.scan oracle (ref.py); the
Pallas flavors run one kernel launch per epoch with the model pinned in
VMEM.  All flavors update in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.glm_sgd import kernel as K
from repro.kernels.glm_sgd import ref as R


@functools.partial(
    jax.jit, static_argnames=("task", "step", "micro_batch", "interpret")
)
def _pallas(task, w, X, y, *, step, micro_batch, interpret):
    """One fused SGD epoch over (X, y); model stays in VMEM throughout.

    N must be divisible by ``micro_batch`` (the data pipeline guarantees
    this); d is padded to the 128-lane tile internally.
    """
    n, d = X.shape
    assert n % micro_batch == 0, (n, micro_batch)
    d_pad = common.padded(d, common.LANE)
    Xp = common.pad_to(X.astype(jnp.float32), 1, d_pad)
    yp = y.astype(jnp.float32).reshape(n, 1)
    wp = common.pad_to(w.astype(jnp.float32).reshape(d, 1), 0, d_pad)
    w_out = K.glm_sgd_pallas(
        task, wp, Xp, yp, step=step, micro_batch=micro_batch, interpret=interpret
    )
    return w_out[:d, 0]


@common.register_kernel("glm_sgd", common.PALLAS_TPU)
def _glm_sgd_tpu(task, w, X, y, *, step, micro_batch=8):
    return _pallas(task, w, X, y, step=step, micro_batch=micro_batch,
                   interpret=False)


@common.register_kernel("glm_sgd", common.PALLAS_INTERPRET)
def _glm_sgd_interpret(task, w, X, y, *, step, micro_batch=8):
    return _pallas(task, w, X, y, step=step, micro_batch=micro_batch,
                   interpret=True)


@common.register_kernel("glm_sgd", common.REFERENCE, caps=common.Caps(dtypes=None))
@functools.partial(jax.jit, static_argnames=("task", "step", "micro_batch"))
def _glm_sgd_reference(task, w, X, y, *, step, micro_batch=8):
    return R.glm_sgd_epoch_ref(
        task, w.astype(jnp.float32), X.astype(jnp.float32),
        y.astype(jnp.float32), step, micro_batch,
    )


def glm_sgd_epoch(
    task: str,
    w: jax.Array,   # [d]
    X: jax.Array,   # [N, d]
    y: jax.Array,   # [N]
    *,
    step: float,
    micro_batch: int = 8,
    backend: str | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """One mini-batch SGD epoch via the best available backend."""
    info = {"dtype": jnp.result_type(X).name, "n": X.shape[0], "d": X.shape[1]}
    return common.dispatch(
        "glm_sgd", task, w, X, y, step=step, micro_batch=micro_batch,
        backend=backend, interpret=interpret, info=info,
    )
