"""Public wrapper for the fused incremental-SGD epoch — registry-dispatched.

The ``reference`` flavor is the sequential lax.scan oracle (ref.py); the
Pallas flavors run one kernel launch per epoch with the model pinned in
VMEM.  All flavors update in fp32.

The Pallas flavors need ``n`` divisible by ``micro_batch`` (the epoch is
a fixed-shape grid of micro-batch tiles): the dispatch caps see both in
the call info, so auto-selection falls through to ``reference`` (which
handles the ragged tail) instead of dying inside the kernel — forcing a
Pallas flavor onto a non-divisible ``n`` raises ``ValueError``.  When
the caller does not pin ``micro_batch``, the per-device autotuner cache
(:mod:`repro.kernels.tune`) is consulted before the built-in default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common, tune
from repro.kernels.glm_sgd import kernel as K
from repro.kernels.glm_sgd import ref as R

#: built-in micro-batch when neither the caller nor the tuner pins one
DEFAULT_MICRO_BATCH = 8


def _check_divisible(n: int, micro_batch: int) -> None:
    if micro_batch < 1 or n % micro_batch:
        raise ValueError(
            f"glm_sgd Pallas flavors need n % micro_batch == 0, got "
            f"n={n}, micro_batch={micro_batch}; drop the explicit backend "
            f"to fall through to 'reference' (ragged-tail oracle) or pick "
            f"a divisor of n")


_PALLAS_CAPS = common.Caps(check=lambda info: (
    info.get("n") is None or info.get("micro_batch") is None
    or (info["micro_batch"] >= 1 and info["n"] % info["micro_batch"] == 0)))


@functools.partial(
    jax.jit, static_argnames=("task", "step", "micro_batch", "interpret")
)
def _pallas(task, w, X, y, *, step, micro_batch, interpret):
    """One fused SGD epoch over (X, y); model stays in VMEM throughout.

    N must be divisible by ``micro_batch`` (checked, ValueError); d is
    padded to the 128-lane tile internally.
    """
    n, d = X.shape
    _check_divisible(n, micro_batch)
    d_pad = common.padded(d, common.LANE)
    Xp = common.pad_to(X.astype(jnp.float32), 1, d_pad)
    yp = y.astype(jnp.float32).reshape(n, 1)
    wp = common.pad_to(w.astype(jnp.float32).reshape(d, 1), 0, d_pad)
    w_out = K.glm_sgd_pallas(
        task, wp, Xp, yp, step=step, micro_batch=micro_batch, interpret=interpret
    )
    return w_out[:d, 0]


@common.register_kernel("glm_sgd", common.PALLAS_TPU, caps=_PALLAS_CAPS)
def _glm_sgd_tpu(task, w, X, y, *, step, micro_batch=DEFAULT_MICRO_BATCH):
    return _pallas(task, w, X, y, step=step, micro_batch=micro_batch,
                   interpret=False)


@common.register_kernel("glm_sgd", common.PALLAS_INTERPRET, caps=_PALLAS_CAPS)
def _glm_sgd_interpret(task, w, X, y, *, step, micro_batch=DEFAULT_MICRO_BATCH):
    return _pallas(task, w, X, y, step=step, micro_batch=micro_batch,
                   interpret=True)


@common.register_kernel("glm_sgd", common.REFERENCE, caps=common.Caps(dtypes=None))
@functools.partial(jax.jit, static_argnames=("task", "step", "micro_batch"))
def _glm_sgd_reference(task, w, X, y, *, step, micro_batch=DEFAULT_MICRO_BATCH):
    return R.glm_sgd_epoch_ref(
        task, w.astype(jnp.float32), X.astype(jnp.float32),
        y.astype(jnp.float32), step, micro_batch,
    )


def glm_sgd_epoch(
    task: str,
    w: jax.Array,   # [d]
    X: jax.Array,   # [N, d]
    y: jax.Array,   # [N]
    *,
    step: float,
    micro_batch: int | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """One mini-batch SGD epoch via the best available backend.

    ``micro_batch=None`` consults the autotuner cache for this
    (backend, device, shape-class) before falling back to
    ``DEFAULT_MICRO_BATCH``.
    """
    n, d = X.shape
    info = {"dtype": jnp.result_type(X).name, "n": n, "d": d}
    if micro_batch is None:
        b0 = common.resolve_backend("glm_sgd", backend=backend,
                                    interpret=interpret, info=info)
        run = None
        if tune.timeable(w, X, y):
            run = lambda **cfg: common.dispatch(  # noqa: E731
                "glm_sgd", task, w, X, y, step=step, backend=b0, **cfg)
        micro_batch = tune.consult("glm_sgd", b0, info, run) \
            .get("micro_batch", DEFAULT_MICRO_BATCH)
    info["micro_batch"] = micro_batch
    return common.dispatch(
        "glm_sgd", task, w, X, y, step=step, micro_batch=micro_batch,
        backend=backend, interpret=interpret, info=info,
    )
