"""Jitted public wrapper for the fused incremental-SGD epoch kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.glm_sgd import kernel as K


@functools.partial(
    jax.jit, static_argnames=("task", "step", "micro_batch", "interpret")
)
def glm_sgd_epoch(
    task: str,
    w: jax.Array,   # [d]
    X: jax.Array,   # [N, d]
    y: jax.Array,   # [N]
    *,
    step: float,
    micro_batch: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """One fused SGD epoch over (X, y); model stays in VMEM throughout.

    N must be divisible by ``micro_batch`` (the data pipeline guarantees
    this); d is padded to the 128-lane tile internally.
    """
    interpret = common.resolve_interpret(interpret)
    n, d = X.shape
    assert n % micro_batch == 0, (n, micro_batch)
    d_pad = common.padded(d, common.LANE)
    Xp = common.pad_to(X.astype(jnp.float32), 1, d_pad)
    yp = y.astype(jnp.float32).reshape(n, 1)
    wp = common.pad_to(w.astype(jnp.float32).reshape(d, 1), 0, d_pad)
    w_out = K.glm_sgd_pallas(
        task, wp, Xp, yp, step=step, micro_batch=micro_batch, interpret=interpret
    )
    return w_out[:d, 0]
