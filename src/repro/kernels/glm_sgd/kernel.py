"""Fused incremental-SGD epoch Pallas kernel — the Hogwild-kernel analogue.

The paper's asynchronous GPU kernel fuses gradient computation and model
update into one function that runs per example (Section 5).  On TPU the
grid steps of a core execute *sequentially*, so the same fusion gives a
deterministic incremental/mini-batch SGD pass with the model held in VMEM
scratch across the entire epoch shard:

    grid step k:  load example tile X_k [MB, d] (HBM->VMEM stream)
                  margins = y_k * (X_k @ w_vmem)          (MXU)
                  w_vmem -= (alpha/MB) * X_k^T pull        (MXU + VPU)

One kernel launch = one epoch over the shard = N/MB model updates, zero HBM
traffic for the model (it never leaves VMEM until the final write-out).
This is the TPU-native answer to "model access must be coalesced": the model
is pinned on-chip, so every update is a VMEM-bandwidth operation.  There are
no intra-core write conflicts to stagger (the GPU warp-shuffle trick is
unnecessary by construction — see DESIGN.md §2); cross-core asynchrony is
provided by the replica-merge engine on top.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def _pull(task, margins, y):
    if task == "lr":
        return -y * jax.nn.sigmoid(-margins)
    return -y * (margins < 1.0).astype(margins.dtype)


def _kernel(task, scale, x_ref, y_ref, w0_ref, out_ref, w_s):
    @pl.when(pl.program_id(0) == 0)
    def _():
        w_s[...] = w0_ref[...]

    X = x_ref[...]                    # [MB, d]
    y = y_ref[...]                    # [MB, 1]
    w = w_s[...]                      # [d, 1]
    margins = y * jnp.dot(X, w, preferred_element_type=jnp.float32)
    pull = _pull(task, margins, y)
    g = jax.lax.dot_general(          # X^T @ pull
        X, pull, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    w_s[...] = w - scale * g

    @pl.when(pl.program_id(0) == pl.num_programs(0) - 1)
    def _():
        out_ref[...] = w_s[...]


def glm_sgd_pallas(
    task: str,
    w0: jax.Array,    # [d_pad, 1]
    X: jax.Array,     # [N, d_pad]
    y: jax.Array,     # [N, 1]
    *,
    step: float,
    micro_batch: int,
    interpret: bool,
) -> jax.Array:
    n, d_pad = X.shape
    assert n % micro_batch == 0, (n, micro_batch)
    grid = (n // micro_batch,)
    body = functools.partial(_kernel, task, step / micro_batch)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((micro_batch, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((micro_batch, 1), lambda i: (i, 0)),
            pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d_pad, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d_pad, 1), jnp.float32)],
        compiler_params=common.tpu_compiler_params(
            dimension_semantics=("arbitrary",),  # sequential: state carried
        ),
        interpret=interpret,
    )(X, y, w0)
