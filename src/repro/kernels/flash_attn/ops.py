"""Jitted public wrapper for blocked attention (GQA-aware)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.flash_attn import kernel as K
from repro.kernels.flash_attn import ref as R


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, Hq, Sq, hd]
    k: jax.Array,  # [B, Hkv, Sk, hd]
    v: jax.Array,  # [B, Hkv, Sk, hd]
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int | None = None,
    block_k: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Flash attention with GQA broadcast.  Returns [B, Hq, Sq, hd]."""
    interpret = common.resolve_interpret(interpret)
    b, hq, sq, hd = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    if hkv != hq:  # GQA: broadcast kv heads to query groups
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)

    scale = 1.0 / (hd ** 0.5)
    sk = k.shape[2]
    bq = block_q or common.pick_block(sq, 128, 8)
    bk = block_k or common.pick_block(sk, 128, 8)

    out = K.flash_attention_pallas(
        q.reshape(b * hq, sq, hd),
        k.reshape(b * hq, sk, hd),
        v.reshape(b * hq, sk, hd),
        causal=causal, window=window, scale=scale,
        block_q=bq, block_k=bk, interpret=interpret,
    )
    return out.reshape(b, hq, sq, hd)


# re-export the oracle for tests
attention_ref = R.attention_ref
