"""Public wrapper for blocked attention (GQA-aware) — registry-dispatched.

Registered flavors receive the raw GQA tensors and broadcast kv heads
inside their jitted bodies (so the repeat fuses into the compiled
computation).  The Pallas flavors carry a head-dim sublane constraint;
odd head dims auto-route to the dense ``reference`` oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common, tune
from repro.kernels.flash_attn import kernel as K
from repro.kernels.flash_attn import ref as R


def _seq_blocks_ok(info: dict) -> bool:
    """Pallas needs seq extents blockable: pinned blocks must divide the
    sequence; unpinned sequences must be sublane-aligned so ``pick_block``
    can find an aligned block (it raises on prime/odd extents now instead
    of silently returning a misaligned one)."""
    for s_key, b_key in (("seq_q", "block_q"), ("seq_k", "block_k")):
        s, b = info.get(s_key), info.get(b_key)
        if s is None:
            continue
        if b is not None:
            if s % b != 0:
                return False
        elif s % common.SUBLANE != 0:
            return False
    return True


_PALLAS_CAPS = common.Caps(head_dim_multiple=common.SUBLANE,
                           check=_seq_blocks_ok)


def _gqa_broadcast(q, k, v):
    hq, hkv = q.shape[1], k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    if hkv != hq:  # GQA: broadcast kv heads to query groups
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    return k, v


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def _pallas(q, k, v, *, causal, window, block_q, block_k, interpret):
    k, v = _gqa_broadcast(q, k, v)
    b, h, sq, hd = q.shape
    sk = k.shape[2]
    scale = 1.0 / (hd ** 0.5)
    bq = block_q or common.pick_block(sq, 128, 8)
    bk = block_k or common.pick_block(sk, 128, 8)
    out = K.flash_attention_pallas(
        q.reshape(b * h, sq, hd),
        k.reshape(b * h, sk, hd),
        v.reshape(b * h, sk, hd),
        causal=causal, window=window, scale=scale,
        block_q=bq, block_k=bk, interpret=interpret,
    )
    return out.reshape(b, h, sq, hd)


@common.register_kernel("flash_attn", common.PALLAS_TPU, caps=_PALLAS_CAPS)
def _flash_attn_tpu(q, k, v, *, causal=True, window=None, block_q=None,
                    block_k=None):
    return _pallas(q, k, v, causal=causal, window=window, block_q=block_q,
                   block_k=block_k, interpret=False)


@common.register_kernel("flash_attn", common.PALLAS_INTERPRET, caps=_PALLAS_CAPS)
def _flash_attn_interpret(q, k, v, *, causal=True, window=None, block_q=None,
                          block_k=None):
    return _pallas(q, k, v, causal=causal, window=window, block_q=block_q,
                   block_k=block_k, interpret=True)


@common.register_kernel("flash_attn", common.REFERENCE,
                        caps=common.Caps(dtypes=None))
@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def _flash_attn_reference(q, k, v, *, causal=True, window=None, block_q=None,
                          block_k=None):
    del block_q, block_k
    k, v = _gqa_broadcast(q, k, v)
    return R.attention_ref(q, k, v, causal=causal, window=window)


def flash_attention(
    q: jax.Array,  # [B, Hq, Sq, hd]
    k: jax.Array,  # [B, Hkv, Sk, hd]
    v: jax.Array,  # [B, Hkv, Sk, hd]
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int | None = None,
    block_k: int | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Flash attention with GQA broadcast.  Returns [B, Hq, Sq, hd].

    Unpinned ``block_q``/``block_k`` consult the autotuner cache
    (:mod:`repro.kernels.tune`), then fall back to ``pick_block``'s
    lane-aligned heuristic inside the Pallas wrapper.
    """
    hd = q.shape[3]
    assert q.shape[1] % k.shape[1] == 0, (q.shape[1], k.shape[1])
    info = {"dtype": jnp.result_type(q).name, "head_dim": hd,
            "seq_q": q.shape[2], "seq_k": k.shape[2]}
    if block_q is not None:
        info["block_q"] = block_q
    if block_k is not None:
        info["block_k"] = block_k
    b = common.resolve_backend("flash_attn", backend=backend,
                               interpret=interpret, info=info)
    if block_q is None and block_k is None:
        run = None
        if tune.timeable(q, k, v):
            run = lambda **cfg: common.dispatch(  # noqa: E731
                "flash_attn", q, k, v, causal=causal, window=window,
                backend=b, **cfg)
        cfg = tune.consult("flash_attn", b, info, run)
        block_q, block_k = cfg.get("block_q"), cfg.get("block_k")
    return common.dispatch(
        "flash_attn", q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k,
        backend=b, info=info,
    )


# re-export the oracle for tests
attention_ref = R.attention_ref
