"""Blocked (flash) attention Pallas kernel for the LM substrate.

Online-softmax attention tiled for VMEM: a [TQ, hd] query tile stays
resident while [TK, hd] key/value tiles stream through; running max /
normalizer / accumulator live in VMEM scratch.  Supports causal and
sliding-window masking; fully-masked k-tiles are skipped (no MXU work),
which makes causal attention ~2x and SWA ~S/window cheaper — the structural
optimization the roofline hillclimb for prefill shapes relies on.

Grid: (batch*heads, Sq/TQ, Sk/TK) with the k axis innermost ("arbitrary"
semantics: the scratch carries softmax state across k steps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common

NEG_INF = -1e30


def _kernel(
    causal, window, scale, block_q, block_k, seq_q, seq_k,
    q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s,
):
    iq = pl.program_id(1)
    jk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(jk == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    # global positions; query ends aligned to key ends (decode: seq_q < seq_k)
    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    ) + (seq_k - seq_q)
    k_pos = jk * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )

    # tile-level visibility: skip fully-masked tiles entirely
    q_hi = iq * block_q + block_q - 1 + (seq_k - seq_q)
    k_lo = jk * block_k
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_lo <= q_hi)
    if window is not None:
        q_lo = iq * block_q + (seq_k - seq_q)
        k_hi = jk * block_k + block_k - 1
        run = jnp.logical_and(run, k_hi > q_lo - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0]                  # [TQ, hd]
        k = k_ref[0]                  # [TK, hd]
        v = v_ref[0]                  # [TK, hd]
        s = jax.lax.dot_general(      # q @ k^T
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                     # [TQ, TK]
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_s[...]             # [TQ, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)        # [TQ, TK]
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_s[...] = m_new
        acc[...] = acc[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )

    @pl.when(jk == nk - 1)
    def _():
        l = l_s[...]
        o_ref[0] = (acc[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # [BH, Sq, hd]
    k: jax.Array,  # [BH, Sk, hd]
    v: jax.Array,  # [BH, Sk, hd]
    *,
    causal: bool,
    window: int | None,
    scale: float,
    block_q: int,
    block_k: int,
    interpret: bool,
) -> jax.Array:
    bh, sq, hd = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    grid = (bh, sq // block_q, sk // block_k)
    body = functools.partial(
        _kernel, causal, window, scale, block_q, block_k, sq, sk
    )
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),   # output accumulator
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running normalizer
        ],
        compiler_params=common.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
