"""Pure-jnp oracle for blocked (flash) attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # [B, H, Sq, hd]
    k: jax.Array,  # [B, H, Sk, hd]
    v: jax.Array,  # [B, H, Sk, hd]
    *,
    causal: bool = True,
    scale: float | None = None,
    window: int | None = None,
) -> jax.Array:
    """Dense softmax attention with optional causal / sliding-window mask.

    Assumes q/k head counts already match (GQA broadcast handled by caller).
    ``window``: sliding-window attention — key j visible to query i iff
    i - window < j <= i (combined with causal).
    """
    hd = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd).astype(q.dtype)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    sq, sk = q.shape[2], k.shape[2]
    qi = jnp.arange(sq)[:, None] + (sk - sq)  # align ends (decode: sq < sk)
    kj = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= qi >= kj
    if window is not None:
        mask &= qi - kj < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
