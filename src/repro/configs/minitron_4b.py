"""minitron-4b — pruned nemotron [arXiv:2407.14679; hf]."""
import jax.numpy as jnp
from repro.nn.transformer import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b", family="dense", n_layers=32, d_model=3072,
    n_heads=24, n_kv=8, d_ff=9216, vocab=256_000,
    ffn_gated=False,                      # squared-ReLU MLP (nemotron)
    head_dim=128, seq_shard=True, param_dtype=jnp.bfloat16,
    notes="pruned nemotron; full attention -> long_500k skipped",
)
