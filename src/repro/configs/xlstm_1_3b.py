"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]."""
import jax.numpy as jnp
from repro.nn.transformer import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=4, n_kv=4, d_ff=0, vocab=50_304,
    seq_shard=False,  # hillclimb-2: chunk math is S-axis-local; SP resharding cost it X~2x
    param_dtype=jnp.bfloat16,
    ssm_chunk=512,  # hillclimb-2: halves per-chunk state saves vs 256,
    notes=("superblocks of 7 mLSTM + 1 sLSTM; d_ff=0 — up/down projections "
           "live inside the blocks; chunked-parallel train, recurrent "
           "decode; runs long_500k"),
)
