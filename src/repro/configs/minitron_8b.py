"""minitron-8b — pruned nemotron [arXiv:2407.14679; hf]."""
import jax.numpy as jnp
from repro.nn.transformer import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv=8, d_ff=16384, vocab=256_000,
    ffn_gated=False, head_dim=128, seq_shard=True, param_dtype=jnp.bfloat16,
    notes="pruned nemotron; full attention -> long_500k skipped",
)
