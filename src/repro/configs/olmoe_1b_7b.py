"""olmoe-1b-7b — 64 experts top-8 [arXiv:2409.02060; hf]."""
import jax.numpy as jnp
from repro.nn.transformer import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv=16, d_ff=1024, vocab=50_304,
    moe_experts=64, moe_top_k=8, head_dim=128, seq_shard=True,
    param_dtype=jnp.bfloat16,
    notes="64e top-8 MoE (d_ff=1024 per expert); EP over model axis",
)
