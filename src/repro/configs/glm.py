"""The paper's own tasks as selectable configs: {dataset} x {LR, SVM}.

These drive the GLM benchmarks and examples the same way the LM arch
configs drive the dry-run: ``get_glm("news-lr")`` returns everything needed
to instantiate the training problem.
"""
from __future__ import annotations

import dataclasses

from repro.core import sgd
from repro.data import synthetic


@dataclasses.dataclass(frozen=True)
class GLMTaskConfig:
    name: str
    dataset: str                 # synthetic Table-3 stand-in name
    task: str                    # "lr" | "svm"
    default_strategy: str = "sync"
    # paper Table 6 optimal async configuration, translated to our engine
    async_access: str = "chunk"
    async_rep_k: int = 0
    async_replicas: int = 8

    def make_dataset(self, *, max_n: int | None = 8192, seed: int = 0):
        return synthetic.paper_dataset(self.dataset, max_n=max_n, seed=seed)

    def async_strategy(self) -> "sgd.AsyncLocalSGD":
        return sgd.AsyncLocalSGD(replicas=self.async_replicas, local_batch=1,
                                 access=self.async_access,
                                 rep_k=self.async_rep_k)


# paper Table 6 (optimal Hogwild configs) mapped to engine knobs:
#   row-rr/row-ch -> access; rep-10 -> rep_k=10; kernel/block -> replicas
_TABLE6 = {
    ("covtype", "lr"): ("chunk", 0),     # col-rr + block + no-rep
    ("w8a", "lr"): ("round_robin", 10),  # row-rr + kernel + rep-10
    ("real-sim", "lr"): ("chunk", 10),   # row-ch + kernel + rep-10
    ("rcv1", "lr"): ("chunk", 0),        # row-ch + kernel + no-rep
    ("news", "lr"): ("round_robin", 10),
    ("covtype", "svm"): ("chunk", 0),
    ("w8a", "svm"): ("chunk", 10),
    ("real-sim", "svm"): ("round_robin", 10),
    ("rcv1", "svm"): ("round_robin", 10),
    ("news", "svm"): ("round_robin", 10),
}

GLM_CONFIGS = {
    f"{ds}-{task}": GLMTaskConfig(
        name=f"{ds}-{task}", dataset=ds, task=task,
        async_access=_TABLE6[(ds, task)][0],
        async_rep_k=_TABLE6[(ds, task)][1])
    for (ds, task) in _TABLE6
}


def get_glm(name: str) -> GLMTaskConfig:
    return GLM_CONFIGS[name]
