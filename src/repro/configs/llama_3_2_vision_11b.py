"""llama-3.2-vision-11b — cross-attn image layers [hf:meta-llama; unverified]."""
import jax.numpy as jnp
from repro.nn.transformer import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm", n_layers=40, d_model=4096,
    n_heads=32, n_kv=8, d_ff=14336, vocab=128_256,
    cross_every=5, n_memory=1600, head_dim=128, seq_shard=True,
    param_dtype=jnp.bfloat16,
    notes=("text decoder w/ cross-attention every 5th layer; vision frontend "
           "is a stub — input_specs() provides 1600 patch embeddings; full "
           "attention -> long_500k skipped"),
)
