"""h2o-danube-1.8b — llama+mistral mix, SWA [arXiv:2401.16818; hf]."""
import jax.numpy as jnp
from repro.nn.transformer import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b", family="dense", n_layers=24, d_model=2560,
    n_heads=32, n_kv=8, d_ff=6912, vocab=32_000,
    ffn_gated=True, window=4096, head_dim=80, seq_shard=True,
    param_dtype=jnp.bfloat16,
    notes="sliding-window attention (4096) -> sub-quadratic; runs long_500k",
)
