"""zamba2-1.2b — Mamba2 + shared attn blocks [arXiv:2411.15242; hf]."""
import jax.numpy as jnp
from repro.nn.transformer import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv=32, d_ff=8192, vocab=32_000, ssm_state=64,
    head_dim=64, seq_shard=True, param_dtype=jnp.bfloat16,
    notes=("Mamba2 backbone, one weight-tied attention block applied per 6 "
           "mamba layers; runs long_500k (O(1) SSM state; shared attention "
           "ring-cached at 4096 in long-context mode)"),
)
