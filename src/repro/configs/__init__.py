"""Architecture registry + input-shape suite + reduced smoke configs."""
from __future__ import annotations

import dataclasses
import importlib

import jax.numpy as jnp

from repro.nn.transformer import ArchConfig

_MODULES = {
    "minitron-4b": "minitron_4b",
    "command-r-35b": "command_r_35b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "minitron-8b": "minitron_8b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "musicgen-large": "musicgen_large",
    "zamba2-1.2b": "zamba2_1_2b",
    "xlstm-1.3b": "xlstm_1_3b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
}

ARCH_NAMES = tuple(_MODULES)

# shape id -> (kind, seq_len, global_batch)
SHAPES = {
    "train_4k": ("train", 4_096, 256),
    "prefill_32k": ("prefill", 32_768, 32),
    "decode_32k": ("decode", 32_768, 128),
    "long_500k": ("decode", 524_288, 1),
}

# archs with a sub-quadratic sequence path (run long_500k); all others skip
SUBQUADRATIC = ("h2o-danube-1.8b", "zamba2-1.2b", "xlstm-1.3b")


def get(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells.  long_500k only for sub-quadratic
    archs unless include_skipped."""
    out = []
    for a in ARCH_NAMES:
        for s in SHAPES:
            if s == "long_500k" and a not in SUBQUADRATIC and not include_skipped:
                continue
            out.append((a, s))
    return out


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests (shape contract only)."""
    fam = cfg.family
    n_layers = {"dense": 2, "moe": 2, "audio": 2, "vlm": 5,
                "hybrid": 8, "ssm": 8}[fam]
    kw = dict(
        name=cfg.name + "-smoke", family=fam, n_layers=n_layers,
        d_model=64, n_heads=4, n_kv=2 if cfg.n_kv < cfg.n_heads else 4,
        d_ff=0 if cfg.d_ff == 0 else 128, vocab=256,
        moe_experts=8 if cfg.moe_experts else 0,
        moe_top_k=2 if cfg.moe_top_k else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        window=16 if cfg.window else None,
        cross_every=cfg.cross_every, n_memory=16 if cfg.n_memory else 0,
        ffn_gated=cfg.ffn_gated, fsdp=False, seq_shard=False,
        param_dtype=jnp.float32, head_dim=16,
        attn_chunk=16, loss_chunk=16, ssm_chunk=8,
    )
    kw.update(overrides)
    return ArchConfig(**kw)


def parse_dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]
