"""kimi-k2-1t-a32b — trillion-param MoE (paper-table) [arXiv:2501.kimi2]."""
import jax.numpy as jnp
from repro.nn.transformer import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv=8, d_ff=2048, vocab=163_840,
    moe_experts=384, moe_top_k=8, head_dim=112, fsdp=True, seq_shard=True,
    param_dtype=jnp.bfloat16,
    notes=("~1T total / 32B active; experts sharded EP x FSDP; needs >=512 "
           "chips for training memory (recorded in EXPERIMENTS.md)"),
)
