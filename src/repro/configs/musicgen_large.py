"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284; hf]."""
import jax.numpy as jnp
from repro.nn.transformer import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio", n_layers=48, d_model=2048,
    n_heads=32, n_kv=32, d_ff=8192, vocab=2048,
    ffn_gated=True, head_dim=64, seq_shard=True, param_dtype=jnp.bfloat16,
    notes=("backbone only: EnCodec frontend is a stub — input_specs() "
           "provides precomputed frame embeddings [B,S,d]; head over the "
           "2048-entry codec vocab; full attention -> long_500k skipped"),
)
