"""command-r-35b — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01; unverified]."""
import jax.numpy as jnp
from repro.nn.transformer import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b", family="dense", n_layers=40, d_model=8192,
    n_heads=64, n_kv=8, d_ff=22528, vocab=256_000,
    ffn_gated=True, head_dim=128, fsdp=True, seq_shard=True,
    param_dtype=jnp.bfloat16,
    notes="35B dense; FSDP over data axis; full attention -> long_500k skipped",
)
