"""Adam (for the LM example applications; the paper study itself uses SGD)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.sgd import Optimizer, _to_schedule


def adam(lr, b1=0.9, b2=0.95, eps=1e-8, *, state_dtype=jnp.float32) -> Optimizer:
    sched = _to_schedule(lr)

    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=state_dtype)  # noqa: E731
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = sched(step)
        m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g.astype(mi.dtype),
                         state["m"], grads)
        v = jax.tree.map(
            lambda vi, g: b2 * vi + (1 - b2) * jnp.square(g.astype(vi.dtype)),
            state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        updates = jax.tree.map(
            lambda mi, vi: (-lr_t * (mi / bc1) /
                            (jnp.sqrt(vi / bc2) + eps)),
            m, v)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update, "adam")
