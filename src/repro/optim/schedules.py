"""Learning-rate schedules (step-decay is the paper's diminishing-step rule)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def inverse_time(lr0: float, decay: float = 0.01):
    """alpha_t = alpha_0 / (1 + decay * t) — classic Robbins-Monro-style."""
    return lambda step: lr0 / (1.0 + decay * step.astype(jnp.float32))


def cosine(lr0: float, total_steps: int, warmup: int = 0, floor: float = 0.0):
    def f(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr0 * warm * cos
    return f
