from repro.optim.sgd import sgd, sgd_momentum  # noqa: F401
from repro.optim.adam import adam  # noqa: F401
from repro.optim import schedules, clip, compress  # noqa: F401
