"""Gradient compression for the slow (cross-pod / DCN) merge path.

int8 block quantization with error feedback (the Buckwild [8] low-precision
idea applied where the paper's analysis says asynchrony/compression pays:
the expensive interconnect boundary).  The replica-merge engine compresses
the cross-pod model delta, accumulating quantization error locally so the
merged model stays unbiased over time (error-feedback SGD).

All functions are jit-friendly: quantized trees are ``{"q": int8-tree,
"s": fp32-scale-tree}`` and dequantization takes the original tree as the
shape/dtype reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_amount(n: int) -> int:
    return (-n) % BLOCK


def quantize_leaf(x: jax.Array):
    """Per-block symmetric int8 quantization.  Returns (q [Nb, B], s [Nb, 1])."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = _pad_amount(flat.size)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_leaf(q, scale, like: jax.Array):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    pad = _pad_amount(like.size)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(like.shape).astype(like.dtype)


def compress_tree(tree, error_feedback=None):
    """Quantize every leaf with error feedback.

    Returns ``({"q": ..., "s": ...}, new_error_feedback)``; error feedback is
    an fp32 tree of the same structure (zeros on first call)."""
    if error_feedback is None:
        error_feedback = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), tree)

    def one(x, e):
        xe = x.astype(jnp.float32) + e
        q, s = quantize_leaf(xe)
        deq = dequantize_leaf(q, s, xe)
        return q, s, xe - deq

    # ONE pass per leaf: map to (q, s, residual) triples, then transpose
    # the tree-of-triples into three trees
    triples = jax.tree.map(one, tree, error_feedback)
    qs, ss, ef = jax.tree.transpose(
        jax.tree.structure(tree), jax.tree.structure((0, 0, 0)), triples)
    return {"q": qs, "s": ss}, ef


def decompress_tree(compressed, like_tree):
    return jax.tree.map(
        lambda q, s, like: dequantize_leaf(q, s, like),
        compressed["q"], compressed["s"], like_tree)


def compression_ratio(tree) -> float:
    """Bytes(original) / bytes(int8+scales) — reported in benchmarks."""
    orig = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
    comp = sum(x.size + 4 * (x.size // BLOCK + 1)
               for x in jax.tree.leaves(tree))
    return orig / comp
