"""SGD (the paper's optimizer) — plain and momentum variants.

Optimizer protocol (optax-like but dependency-free):

    opt = sgd(lr)
    state = opt.init(params)               # pytree (possibly empty)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]
    name: str = "opt"


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def _to_schedule(lr):
    if callable(lr):
        return lr
    return lambda step: lr


def sgd(lr) -> Optimizer:
    """Plain SGD: u = -lr * g.  State = step counter only."""
    sched = _to_schedule(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"]
        lr_t = sched(step)
        updates = jax.tree.map(lambda g: -lr_t * g, grads)
        return updates, {"step": step + 1}

    return Optimizer(init, update, "sgd")


def sgd_momentum(lr, momentum: float = 0.9, *, state_dtype=None) -> Optimizer:
    """SGD with (optionally low-precision) momentum buffers."""
    sched = _to_schedule(lr)

    def init(params):
        m = jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=state_dtype or p.dtype), params)
        return {"step": jnp.zeros((), jnp.int32), "m": m}

    def update(grads, state, params=None):
        step = state["step"]
        lr_t = sched(step)
        m = jax.tree.map(
            lambda mi, g: (momentum * mi + g).astype(mi.dtype),
            state["m"], grads)
        updates = jax.tree.map(lambda mi: -lr_t * mi, m)
        return updates, {"step": step + 1, "m": m}

    return Optimizer(init, update, "sgd_momentum")
