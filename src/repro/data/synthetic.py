"""Synthetic dataset generators matching the paper's Table 3 statistics.

The container is offline, so covtype / w8a / real-sim / rcv1 / news are
regenerated synthetically with matching (N, d, nnz/example) profiles and a
planted linearly-separable-with-noise structure so LR/SVM actually converge.
``scale`` shrinks N proportionally for CI-speed runs while keeping d and the
sparsity profile; the benchmark harness uses scale<=1 profiles, tests use
tiny scales.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import sparse as sparse_mod

# name -> (N, d, avg_nnz, max_nnz, dense?)   (paper Table 3)
PAPER_DATASETS: dict[str, tuple[int, int, float, int, bool]] = {
    "covtype": (581_012, 54, 54.0, 54, True),
    "w8a": (64_700, 300, 11.65, 114, False),
    "real-sim": (72_309, 20_958, 51.30, 3_484, False),
    "rcv1": (677_399, 47_236, 73.16, 1_224, False),
    "news": (19_996, 1_355_191, 454.99, 16_423, False),
    "skin": (245_057, 3, 3.0, 3, True),
}


@dataclasses.dataclass
class Dataset:
    name: str
    X: np.ndarray | None            # dense [N, d] or None for sparse-only
    ell: "sparse_mod.ELLMatrix | None"
    y: np.ndarray                   # [N] in {-1, +1}
    d: int
    dense: bool
    content_hash: str | None = None  # real data only (repro.data.ingest)

    @property
    def n(self) -> int:
        return len(self.y)


def _planted_labels(rng, X_dot_w: np.ndarray, noise: float = 0.05) -> np.ndarray:
    """Labels from a planted hyperplane with `noise` fraction flipped."""
    y = np.where(X_dot_w >= 0, 1.0, -1.0)
    flip = rng.random(len(y)) < noise
    y[flip] *= -1.0
    return y.astype(np.float32)


def make_dense(
    name: str, n: int, d: int, *, seed: int = 0, noise: float = 0.05
) -> Dataset:
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, size=(n, d)).astype(np.float32)
    w_star = rng.normal(0, 1, size=(d,)).astype(np.float32)
    y = _planted_labels(rng, X @ w_star, noise)
    return Dataset(name=name, X=X, ell=None, y=y, d=d, dense=True)


def make_sparse(
    name: str,
    n: int,
    d: int,
    avg_nnz: float,
    max_nnz: int,
    *,
    seed: int = 0,
    noise: float = 0.05,
    pad_to: int | None = None,
) -> Dataset:
    """Sparse dataset with log-normal nnz/row distribution (long tail like
    real text data) and Zipfian feature popularity (like bag-of-words)."""
    rng = np.random.default_rng(seed)
    # nnz per row: lognormal clipped to [1, max_nnz], mean ~ avg_nnz
    mu = np.log(max(avg_nnz, 1.5))
    nnz = np.clip(rng.lognormal(mu, 0.8, size=n), 1, max_nnz).astype(np.int64)
    # Zipf feature popularity
    ranks = np.arange(1, d + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    w_star = (rng.normal(0, 1, size=d) / np.sqrt(ranks)).astype(np.float32)
    rows_idx, rows_val, margins = [], [], np.zeros(n, dtype=np.float64)
    for i in range(n):
        k = int(nnz[i])
        idx = np.unique(rng.choice(d, size=k, p=probs))
        val = rng.normal(0, 1, size=len(idx)).astype(np.float32)
        rows_idx.append(idx.astype(np.int32))
        rows_val.append(val)
        margins[i] = float(val @ w_star[idx])
    y = _planted_labels(rng, margins, noise)
    K = pad_to if pad_to is not None else int(max(len(r) for r in rows_idx))
    ell = sparse_mod.from_rows(rows_idx, rows_val, d, pad_to=K)
    return Dataset(name=name, X=None, ell=ell, y=y, d=d, dense=False)


def paper_dataset(name: str, *, scale: float = 1.0, seed: int = 0,
                  max_n: int | None = None) -> Dataset:
    """A synthetic stand-in for one of the paper's five datasets.

    ``scale`` multiplies N (sparsity profile preserved); ``max_n`` caps N.
    """
    N, d, avg_nnz, max_nnz, dense = PAPER_DATASETS[name]
    n = int(N * scale)
    if max_n is not None:
        n = min(n, max_n)
    n = max(n, 64)
    if dense:
        return make_dense(name, n, d, seed=seed)
    # cap the pad width at a high percentile to keep ELL memory sane at small n
    pad = min(max_nnz, max(int(avg_nnz * 6), 8))
    return make_sparse(name, n, d, avg_nnz, min(max_nnz, pad), seed=seed, pad_to=pad)


# ---------------------------------------------------------------------------
# LM token streams (for the architecture substrate)
# ---------------------------------------------------------------------------


def token_batch(rng: np.random.Generator, batch: int, seq: int, vocab: int):
    """Uniform random token ids + next-token labels (shape contract only)."""
    tokens = rng.integers(0, vocab, size=(batch, seq), dtype=np.int32)
    labels = np.roll(tokens, -1, axis=1)
    return tokens, labels
