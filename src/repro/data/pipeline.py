"""Sharded input pipeline: LM token batches + GLM partitions with rep-k halos.

The pipeline owns the paper's *data replication* axis (§5.2.3): every data
shard can be extended with ``rep_k`` halo examples from the neighbouring
shard — sequential access is preserved, hardware efficiency drops by k/|shard|
per pass, statistical efficiency rises.

On a real multi-host system each process feeds its addressable devices via
``jax.make_array_from_process_local_data``; in this single-process container
``device_put`` against the global NamedSharding is the same code path.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class TokenPipeline:
    """Deterministic synthetic LM token stream (shape-faithful stand-in for
    a tokenized corpus reader; swap ``_gen`` for a real loader in prod)."""

    vocab: int
    seq: int
    global_batch: int
    mesh: Mesh | None = None
    seed: int = 0
    rep_k: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._sharding = None
        if self.mesh is not None:
            batch_axes = tuple(a for a in ("pod", "data")
                               if a in self.mesh.axis_names)
            self._sharding = NamedSharding(self.mesh, P(batch_axes, None))

    def _gen(self, n: int) -> np.ndarray:
        return self._rng.integers(0, self.vocab, size=(n, self.seq + 1),
                                  dtype=np.int32)

    def __iter__(self) -> Iterator[dict]:
        while True:
            buf = self._gen(self.global_batch)
            batch = {"tokens": buf[:, :-1], "labels": buf[:, 1:]}
            if self._sharding is not None:
                batch = {k: jax.device_put(v, self._sharding)
                         for k, v in batch.items()}
            else:
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
            yield batch


def shard_with_halo(n: int, shards: int, rep_k: int) -> list[np.ndarray]:
    """Contiguous shard index ranges with rep_k cyclic halo (paper §5.2.3)."""
    per = n // shards
    out = []
    for r in range(shards):
        base = np.arange(r * per, (r + 1) * per)
        halo = (np.arange(rep_k) + ((r + 1) % shards) * per) % n
        out.append(np.concatenate([base, halo]).astype(np.int64)
                   if rep_k else base.astype(np.int64))
    return out


def glm_shards(X: np.ndarray, y: np.ndarray, shards: int, rep_k: int = 0):
    """Partition a GLM dataset into per-replica (X, y) shards with halos."""
    idx = shard_with_halo(len(y), shards, rep_k)
    return [(X[i], y[i]) for i in idx]
