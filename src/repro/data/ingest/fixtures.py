"""Deterministic miniature fixtures for the five real datasets.

The container builds offline, so the bundled fixtures are **not**
subsets of the downloaded files: they are seeded miniatures written in
the exact libsvm wire format of each source, matching its Table-3
shape — same feature-space width ``d``, same average row density, same
raw label alphabet (covtype/skin ship {1,2} labels, the text datasets
ship ±1), dense rows written densely.  Parsing a fixture therefore
exercises every code path the full download does (label mapping, base
detection, scaling, ELL conversion) while keeping tier-1 hermetic.

Regenerate with::

    PYTHONPATH=src python -m repro.data.ingest.fixtures

which rewrites ``src/repro/data/ingest/fixtures/<name>.libsvm``
byte-identically (fixed seeds, fixed float precision).
"""
from __future__ import annotations

from pathlib import Path

import numpy as np

from repro.core import sparse as sparse_mod
from repro.data.ingest import libsvm, registry

FIXTURE_DIR = Path(__file__).resolve().parent / "fixtures"

#: fixture example counts — sized so the 80% train split is a power of
#: two (friendly to replica partitioning in the study engine)
FIXTURE_ROWS = {
    "covtype": 160, "w8a": 160, "real-sim": 160, "news": 48, "skin": 320,
}


def _row_nnz(rng, n: int, avg: float, lo: int, hi: int) -> np.ndarray:
    """Long-tailed nnz/row counts whose mean hits ``avg`` exactly."""
    counts = np.clip(rng.lognormal(np.log(max(avg, 1.5)), 0.8, size=n),
                     lo, hi).astype(np.int64)
    target = int(round(avg * n))
    i = 0
    while counts.sum() != target:      # nudge rows within [lo, hi] bounds
        delta = 1 if counts.sum() < target else -1
        j = i % n
        if lo <= counts[j] + delta <= hi:
            counts[j] += delta
        i += 1
    return counts


def make_fixture(name: str, seed: int | None = None):
    """(CSRMatrix, raw_labels) miniature for one registered dataset."""
    meta = registry.get(name)
    n = FIXTURE_ROWS[name]
    rng = np.random.default_rng(
        seed if seed is not None else sum(map(ord, name)))
    d = meta.d
    w_star = None
    if meta.dense:
        X = rng.uniform(0.0, 1.0, size=(n, d)).astype(np.float32)
        if name == "skin":               # raw RGB bytes, like the source
            X = np.floor(X * 256).clip(0, 255)
        rows_idx = [np.arange(d, dtype=np.int64)] * n
        rows_val = [X[i] for i in range(n)]
        w_star = rng.normal(0, 1, size=d).astype(np.float32)
        margins = (X - X.mean(axis=0)) @ w_star
    else:
        cap = min(meta.max_nnz, max(int(meta.avg_nnz * 4), 8))
        nnz = _row_nnz(rng, n, meta.avg_nnz, 1, cap)
        ranks = np.arange(1, d + 1, dtype=np.float64)
        probs = (1.0 / ranks) / (1.0 / ranks).sum()
        w_star = (rng.normal(0, 1, size=d) / np.sqrt(ranks)).astype(np.float32)
        rows_idx, rows_val, margins = [], [], np.zeros(n)
        for i in range(n):
            idx = np.sort(rng.choice(d, size=int(nnz[i]), replace=False,
                                     p=probs))
            val = rng.normal(0, 1, size=len(idx)).astype(np.float32)
            rows_idx.append(idx.astype(np.int64))
            rows_val.append(val)
            margins[i] = float(val @ w_star[idx])
    # planted labels with 5% flip noise, written in the raw alphabet:
    # dense sources (covtype, skin) use {1, 2}, the text sources use ±1
    y = np.where(margins >= np.median(margins), 1.0, -1.0)
    flip = rng.random(n) < 0.05
    y[flip] *= -1.0
    if meta.dense:
        raw = np.where(y > 0, meta.positive_label, 3.0 - meta.positive_label)
    else:
        raw = np.where(y > 0, 1.0, -1.0)
    csr = sparse_mod.from_csr_parts(rows_idx, rows_val, d)
    return csr, raw.astype(np.float32)


def write_all(out_dir: Path = FIXTURE_DIR) -> list[Path]:
    out_dir.mkdir(parents=True, exist_ok=True)
    written = []
    for name in registry.REAL_DATASETS:
        csr, raw = make_fixture(name)
        path = out_dir / f"{name}.libsvm"
        libsvm.write_libsvm(path, csr, raw)
        written.append(path)
        print(f"wrote {path} ({csr.n} rows, {csr.nnz} nnz, "
              f"avg {csr.avg_nnz:.2f})")
    return written


if __name__ == "__main__":
    write_all()
