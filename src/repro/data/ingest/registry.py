"""Registry of the paper's five real datasets (Table 3).

Each entry records the Table-3 statistics of the *full* dataset
(``n``/``d``/``avg_nnz``/``density``/``task``), the canonical LIBSVM
mirror URL, and parsing policy (label mapping, feature scaling).  The
registry is pure metadata — fetching and parsing live in
:mod:`repro.data.ingest.cache` / :mod:`repro.data.ingest.libsvm`.

Integrity hashes: entries whose ``sha256`` is ``None`` use
trust-on-first-use — the first gated download records the observed hash
next to the blob and every later read verifies against it.  Pin a hash
here once a blob is vetted.
"""
from __future__ import annotations

import dataclasses

LIBSVM_BINARY = ("https://www.csie.ntu.edu.tw/~cjlin/libsvmtools/"
                 "datasets/binary/")


@dataclasses.dataclass(frozen=True)
class RealDatasetMeta:
    """Table-3 row + ingestion policy for one real dataset."""

    name: str
    n: int                  # full-dataset example count (Table 3)
    d: int                  # feature count (Table 3)
    avg_nnz: float          # average nonzeros/example (Table 3)
    max_nnz: int            # maximum nonzeros/example (Table 3)
    dense: bool             # dense access path (covtype, skin)
    task: str               # learning task the paper runs on it
    url: str                # canonical full-dataset source
    sha256: str | None      # pinned blob hash (None = trust-on-first-use)
    positive_label: float   # raw label mapped to +1 (everything else → -1)
    scale_features: bool    # apply §6.1 per-feature max-abs scaling

    @property
    def density(self) -> float:
        """Fraction of nonzero entries (Table 3's sparsity column)."""
        return self.avg_nnz / self.d


#: the paper's five real datasets (Table 3), keyed by study name
REAL_DATASETS: dict[str, RealDatasetMeta] = {
    "covtype": RealDatasetMeta(
        name="covtype", n=581_012, d=54, avg_nnz=54.0, max_nnz=54,
        dense=True, task="binary", positive_label=2.0, scale_features=True,
        url=LIBSVM_BINARY + "covtype.libsvm.binary.scale.bz2", sha256=None),
    "w8a": RealDatasetMeta(
        name="w8a", n=64_700, d=300, avg_nnz=11.65, max_nnz=114,
        dense=False, task="binary", positive_label=1.0, scale_features=False,
        url=LIBSVM_BINARY + "w8a", sha256=None),
    "real-sim": RealDatasetMeta(
        name="real-sim", n=72_309, d=20_958, avg_nnz=51.30, max_nnz=3_484,
        dense=False, task="binary", positive_label=1.0, scale_features=False,
        url=LIBSVM_BINARY + "real-sim.bz2", sha256=None),
    "news": RealDatasetMeta(
        name="news", n=19_996, d=1_355_191, avg_nnz=454.99, max_nnz=16_423,
        dense=False, task="binary", positive_label=1.0, scale_features=False,
        url=LIBSVM_BINARY + "news20.binary.bz2", sha256=None),
    "skin": RealDatasetMeta(
        name="skin", n=245_057, d=3, avg_nnz=3.0, max_nnz=3,
        dense=True, task="binary", positive_label=1.0, scale_features=True,
        url=LIBSVM_BINARY + "skin_nonskin", sha256=None),
}


def get(name: str) -> RealDatasetMeta:
    try:
        return REAL_DATASETS[name]
    except KeyError:
        raise KeyError(
            f"unknown real dataset {name!r}; registered: "
            f"{tuple(REAL_DATASETS)}") from None
