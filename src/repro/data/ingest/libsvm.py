"""Streaming libsvm/svmlight parser → host-side CSR (no sklearn).

The format, one example per line::

    <label> [qid:<q>] <index>:<value> <index>:<value> ...  # comment

Robustness rules (each covered in tests/test_ingest.py):

* blank lines and ``#``-comment lines (full-line or trailing) are
  skipped / stripped;
* indices are 1-based per the libsvm convention unless a 0 index is
  observed anywhere (then the whole file is treated as 0-based);
  ``zero_based`` forces either reading;
* label-only rows are valid (an all-zero example);
* duplicate feature ids within a row are summed (the scatter-add
  semantics the repo's ELL layout applies to padded slots anyway);
* ``qid:`` tokens are ignored; arbitrary trailing whitespace is fine.

The parser is a generator over lines, so bz2-compressed full datasets
stream through :func:`parse_file` without materializing the text.
"""
from __future__ import annotations

import bz2
from pathlib import Path
from typing import Iterable, Iterator

import numpy as np

from repro.core import sparse as sparse_mod


class LibsvmFormatError(ValueError):
    """A line that is not valid libsvm (bad token, negative index...)."""


def iter_rows(
    lines: Iterable[str],
) -> Iterator[tuple[float, np.ndarray, np.ndarray]]:
    """Yield ``(label, raw_indices, values)`` per example line.

    Indices are yielded exactly as written (base detection is a
    whole-file question — see :func:`parse_lines`); duplicates are
    already summed and indices sorted ascending.
    """
    for lineno, raw in enumerate(lines, 1):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        tokens = line.split()
        try:
            label = float(tokens[0])
        except ValueError:
            raise LibsvmFormatError(
                f"line {lineno}: bad label {tokens[0]!r}") from None
        idx, val = [], []
        for tok in tokens[1:]:
            if tok.startswith("qid:"):
                continue
            try:
                i_str, v_str = tok.split(":", 1)
                i, v = int(i_str), float(v_str)
            except ValueError:
                raise LibsvmFormatError(
                    f"line {lineno}: bad feature token {tok!r}") from None
            if i < 0:
                raise LibsvmFormatError(
                    f"line {lineno}: negative feature index {i}")
            idx.append(i)
            val.append(v)
        indices = np.asarray(idx, dtype=np.int64)
        values = np.asarray(val, dtype=np.float32)
        if len(indices):
            order = np.argsort(indices, kind="stable")
            indices, values = indices[order], values[order]
            uniq, inverse = np.unique(indices, return_inverse=True)
            if len(uniq) != len(indices):    # duplicate feature ids: sum
                summed = np.zeros(len(uniq), dtype=np.float32)
                np.add.at(summed, inverse, values)
                indices, values = uniq, summed
        yield label, indices, values


def parse_lines(
    lines: Iterable[str],
    *,
    d: int | None = None,
    zero_based: bool | None = None,
) -> tuple[sparse_mod.CSRMatrix, np.ndarray]:
    """Parse an entire stream into ``(CSRMatrix, raw_labels)``.

    ``d`` pins the feature-space width (the registry's Table-3 value —
    a subset file rarely touches the maximum feature id); None infers
    ``max_index + 1`` after base adjustment.  ``zero_based=None``
    auto-detects: libsvm is 1-based unless some row uses index 0.
    """
    labels: list[float] = []
    rows_idx: list[np.ndarray] = []
    rows_val: list[np.ndarray] = []
    saw_zero = False
    max_idx = -1
    for label, idx, val in iter_rows(lines):
        labels.append(label)
        rows_idx.append(idx)
        rows_val.append(val)
        if len(idx):
            saw_zero = saw_zero or int(idx[0]) == 0
            max_idx = max(max_idx, int(idx[-1]))
    base = (0 if saw_zero else 1) if zero_based is None else \
        (0 if zero_based else 1)
    if base == 1:
        if saw_zero:    # only reachable with forced zero_based=False
            raise LibsvmFormatError(
                "feature index 0 in a file forced to 1-based reading")
        rows_idx = [idx - 1 for idx in rows_idx]
        max_idx -= 1
    width = d if d is not None else max_idx + 1
    width = max(width, 1)
    if max_idx >= width:
        raise LibsvmFormatError(
            f"feature index {max_idx} out of range for d={width}")
    csr = sparse_mod.from_csr_parts(rows_idx, rows_val, width)
    return csr, np.asarray(labels, dtype=np.float32)


def parse_file(
    path: str | Path,
    *,
    d: int | None = None,
    zero_based: bool | None = None,
) -> tuple[sparse_mod.CSRMatrix, np.ndarray]:
    """Parse a (possibly bz2-compressed) libsvm file, streaming."""
    path = Path(path)
    opener = bz2.open if path.suffix == ".bz2" else open
    with opener(path, "rt") as f:
        return parse_lines(f, d=d, zero_based=zero_based)


def write_libsvm(
    path: str | Path,
    csr: sparse_mod.CSRMatrix,
    labels: np.ndarray,
    *,
    precision: int = 4,
) -> None:
    """Serialize CSR + labels back to 1-based libsvm text (fixtures)."""
    with open(path, "w") as f:
        for i in range(csr.n):
            idx, val = csr.row(i)
            feats = " ".join(
                f"{int(j) + 1}:{v:.{precision}g}" for j, v in zip(idx, val))
            label = int(labels[i]) if float(labels[i]).is_integer() \
                else labels[i]
            f.write(f"{label} {feats}".rstrip() + "\n")
