"""Content-addressed on-disk dataset cache with integrity hashes.

Layout (root = ``$REPRO_DATA_DIR``, default ``~/.cache/repro-sgd-data``)::

    <root>/blobs/<sha256-prefixed name>   raw downloaded files
    <root>/blobs/<name>.sha256            recorded hash (trust-on-first-use)

Network fetch is **disabled by default**: it runs only when
``REPRO_ALLOW_DOWNLOAD=1`` is set, so every tier-1 path stays hermetic
and resolves from the bundled fixtures instead
(:mod:`repro.data.ingest.fixtures`).  Reads always re-hash the blob and
compare against the pinned registry hash (or the recorded first-use
hash) — a mismatch raises :class:`IntegrityError` rather than silently
training on corrupt data.
"""
from __future__ import annotations

import hashlib
import os
import urllib.parse
import urllib.request
from pathlib import Path


class DownloadDisabledError(RuntimeError):
    """Fetch requested while ``REPRO_ALLOW_DOWNLOAD`` is unset."""


class IntegrityError(RuntimeError):
    """A cached blob no longer matches its recorded/pinned sha256."""


def data_dir() -> Path:
    root = os.environ.get("REPRO_DATA_DIR")
    if root:
        return Path(root)
    return Path.home() / ".cache" / "repro-sgd-data"


def downloads_allowed() -> bool:
    return os.environ.get("REPRO_ALLOW_DOWNLOAD", "") == "1"


def sha256_file(path: Path, chunk: int = 1 << 20) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            block = f.read(chunk)
            if not block:
                return h.hexdigest()
            h.update(block)


def _blob_paths(url: str) -> tuple[Path, Path]:
    """(blob path, recorded-hash sidecar path) for one source URL."""
    fname = Path(urllib.parse.urlparse(url).path).name or "blob"
    blob = data_dir() / "blobs" / fname
    return blob, blob.with_name(blob.name + ".sha256")


def fetch(url: str, *, sha256: str | None = None) -> Path:
    """Return the verified local blob for ``url``, downloading if allowed.

    ``sha256`` pins the expected content hash (registry value).  When it
    is None, the hash observed on first download is recorded in a
    sidecar and later reads verify against that (trust-on-first-use).
    """
    blob, sidecar = _blob_paths(url)
    if not blob.exists():
        if not downloads_allowed():
            raise DownloadDisabledError(
                f"{blob.name} is not cached and downloads are disabled; "
                f"set REPRO_ALLOW_DOWNLOAD=1 to fetch {url} "
                f"(cache root: {data_dir()})")
        blob.parent.mkdir(parents=True, exist_ok=True)
        tmp = blob.with_name(blob.name + f".tmp.{os.getpid()}")
        with urllib.request.urlopen(url) as r, open(tmp, "wb") as out:
            while True:
                block = r.read(1 << 20)
                if not block:
                    break
                out.write(block)
        digest = sha256_file(tmp)
        if sha256 is not None and digest != sha256:
            tmp.unlink()
            raise IntegrityError(
                f"downloaded {url}: sha256 {digest} != pinned {sha256}")
        tmp.replace(blob)
        sidecar.write_text(digest + "\n")
    return verify(blob, expected=sha256)


def verify(blob: Path, *, expected: str | None = None) -> Path:
    """Re-hash ``blob`` and check it against the pinned/recorded hash."""
    sidecar = blob.with_name(blob.name + ".sha256")
    digest = sha256_file(blob)
    pinned = expected
    if pinned is None and sidecar.exists():
        pinned = sidecar.read_text().strip()
    if pinned is None:           # nothing recorded yet: record now
        sidecar.write_text(digest + "\n")
        pinned = digest
    if digest != pinned:
        raise IntegrityError(
            f"{blob}: sha256 {digest} does not match recorded {pinned}; "
            f"delete the blob (and its .sha256 sidecar) to re-fetch")
    return blob
