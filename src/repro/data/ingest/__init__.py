"""Real-dataset ingestion: parser → cache → split/scale → ELL.

The paper's headline numbers (Tables 4–7) are measured on five real
datasets; this package makes them loadable behind the same
``Dataset``/``DatasetSpec`` surface the synthetic stand-ins use:

    >>> from repro.data import ingest
    >>> ds = ingest.load("w8a")                 # bundled fixture, offline
    >>> ds.n, ds.d, ds.dense
    (128, 300, False)
    >>> ingest.content_hash("w8a")              # keys the trial cache
    '...'

Resolution order for the raw bytes:

1. a verified blob in the content-addressed cache
   (``$REPRO_DATA_DIR``, populated only when ``REPRO_ALLOW_DOWNLOAD=1``
   — see :mod:`repro.data.ingest.cache`);
2. the bundled miniature fixture (``fixtures/<name>.libsvm``,
   overridable via ``$REPRO_FIXTURE_DIR``) so tier-1 stays hermetic.

Post-parse processing matches the paper's §6.1 protocol: labels map to
±1 via the registry's ``positive_label``, examples split 80/20
train/test by a seeded permutation, and dense sources get per-feature
max-abs scaling **fit on the train split only**.  Every load option
plus the raw-byte sha256 folds into :func:`content_hash`, which
``TrialSpec.key`` embeds — a changed source file changes every
downstream trial-cache key.
"""
from __future__ import annotations

import hashlib
import json
import os
from pathlib import Path

import numpy as np

from repro.core import sparse as sparse_mod
from repro.data import synthetic
from repro.obs import metrics, trace
from repro.data.ingest import cache, libsvm, registry
from repro.data.ingest.cache import (DownloadDisabledError,  # noqa: F401
                                     IntegrityError)
from repro.data.ingest.registry import REAL_DATASETS, RealDatasetMeta  # noqa: F401

TRAIN_FRACTION = 0.8
SPLITS = ("train", "test", "all")

_parse_memo: dict[tuple, tuple[sparse_mod.CSRMatrix, np.ndarray]] = {}
_digest_memo: dict[str, str] = {}
_profile_memo: dict[tuple, tuple[int, int, float, bool]] = {}
_verified: set[str] = set()     # blobs integrity-checked this process


def clear_cache() -> None:
    """Drop in-process memos (tests that swap fixture/data dirs)."""
    _parse_memo.clear()
    _digest_memo.clear()
    _profile_memo.clear()
    _verified.clear()


# ---------------------------------------------------------------------------
# Source resolution
# ---------------------------------------------------------------------------


def fixture_dir() -> Path:
    override = os.environ.get("REPRO_FIXTURE_DIR")
    if override:
        return Path(override)
    return Path(__file__).resolve().parent / "fixtures"


def fixture_path(name: str) -> Path:
    registry.get(name)
    return fixture_dir() / f"{name}.libsvm"


def source_path(name: str) -> tuple[Path, str]:
    """(path, kind) of the best available raw bytes for ``name``.

    ``kind`` is ``"full"`` when a verified cached download exists,
    ``"fixture"`` otherwise.  Never touches the network itself — use
    :func:`fetch_full` (gated) to populate the blob cache.
    """
    meta = registry.get(name)
    blob, _ = cache._blob_paths(meta.url)
    if blob.exists():
        # verify once per process: every TrialSpec.key access lands here
        # via content_hash, and re-hashing a multi-hundred-MB blob per
        # trial would dominate a sweep
        if str(blob) not in _verified:
            with trace.span("ingest.verify", dataset=name):
                cache.verify(blob, expected=meta.sha256)
            _verified.add(str(blob))
        return blob, "full"
    fx = fixture_path(name)
    if not fx.exists():
        raise FileNotFoundError(
            f"no cached blob and no fixture for {name!r} (looked at "
            f"{blob} and {fx})")
    return fx, "fixture"


def fetch_full(name: str) -> Path:
    """Download + verify the full dataset (needs REPRO_ALLOW_DOWNLOAD=1)."""
    meta = registry.get(name)
    return cache.fetch(meta.url, sha256=meta.sha256)


def raw_digest(name: str) -> str:
    """sha256 of the resolved raw source bytes (memoized per path)."""
    path, _ = source_path(name)
    key = str(path)
    if key not in _digest_memo:
        _digest_memo[key] = cache.sha256_file(path)
    return _digest_memo[key]


# ---------------------------------------------------------------------------
# Content hashing (trial-cache keys)
# ---------------------------------------------------------------------------


def content_hash(name: str, *, split: str = "train",
                 max_n: int | None = None, seed: int = 0) -> str:
    """16-hex digest of (raw bytes, every load option).

    This is what distinguishes two runs named "w8a" whose underlying
    data differ — it keys the study trial cache for real datasets.
    """
    payload = {"ingest": 1, "raw": raw_digest(name), "split": split,
               "max_n": max_n, "seed": seed}
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# Load pipeline: parse → label map → split → scale → ELL/dense
# ---------------------------------------------------------------------------


def _parsed(name: str) -> tuple[sparse_mod.CSRMatrix, np.ndarray]:
    meta = registry.get(name)
    path, _ = source_path(name)
    key = (name, str(path), raw_digest(name))
    if key not in _parse_memo:
        metrics.counter("ingest.parse_memo.miss").inc()
        with trace.span("ingest.parse", dataset=name):
            _parse_memo[key] = libsvm.parse_file(path, d=meta.d)
    else:
        metrics.counter("ingest.parse_memo.hit").inc()
    return _parse_memo[key]


def split_rows(n: int, split: str, seed: int) -> np.ndarray:
    """Deterministic 80/20 row split (sorted for access locality)."""
    if split not in SPLITS:
        raise ValueError(f"split must be one of {SPLITS}, got {split!r}")
    if split == "all":
        return np.arange(n)
    perm = np.random.default_rng(seed).permutation(n)
    n_train = int(n * TRAIN_FRACTION)
    rows = perm[:n_train] if split == "train" else perm[n_train:]
    return np.sort(rows)


def feature_scales(csr: sparse_mod.CSRMatrix,
                   fit_rows: np.ndarray) -> np.ndarray:
    """Per-feature max-abs over ``fit_rows`` (1.0 for untouched features).

    Max-abs keeps zeros zero, so scaling never densifies a sparse
    matrix — the §6.1-compatible choice for libsvm-style data.
    """
    fit = csr.select(fit_rows)
    scales = np.zeros(csr.d, dtype=np.float32)
    np.maximum.at(scales, fit.indices, np.abs(fit.values))
    scales[scales == 0.0] = 1.0
    return scales


def _apply_scales(csr: sparse_mod.CSRMatrix,
                  scales: np.ndarray) -> sparse_mod.CSRMatrix:
    return csr._replace(values=(csr.values / scales[csr.indices])
                        .astype(np.float32))


def load(name: str, *, split: str = "train", max_n: int | None = None,
         seed: int = 0) -> synthetic.Dataset:
    """Materialize one real dataset as a study-engine ``Dataset``.

    Dense sources produce ``X [n, d]``; sparse sources produce the ELL
    layout from :mod:`repro.core.sparse`, padded to the split's maximum
    row width — the paper's §5.2.1 format, so **no feature is ever
    dropped**.  That width is what makes full news/real-sim ELL large
    (see docs/DATASETS.md); cap memory with ``max_n``.  ``max_n`` caps
    rows *after* the split.  The returned dataset carries
    :func:`content_hash` in ``content_hash``.
    """
    meta = registry.get(name)
    csr, raw_labels = _parsed(name)
    rows = split_rows(csr.n, split, seed)
    if max_n is not None:
        rows = rows[:max_n]
    y = np.where(raw_labels == meta.positive_label, 1.0, -1.0) \
        .astype(np.float32)[rows]
    sub = csr.select(rows)
    if meta.scale_features:
        scales = feature_scales(csr, split_rows(csr.n, "train", seed)
                                if split != "all" else np.arange(csr.n))
        sub = _apply_scales(sub, scales)
    chash = content_hash(name, split=split, max_n=max_n, seed=seed)
    if meta.dense:
        return synthetic.Dataset(name=name, X=sub.to_dense(), ell=None,
                                 y=y, d=meta.d, dense=True,
                                 content_hash=chash)
    ell = sub.to_ell()       # pads to the max row width: lossless
    return synthetic.Dataset(name=name, X=None, ell=ell, y=y, d=meta.d,
                             dense=False, content_hash=chash)


def profile(name: str, *, split: str = "train", max_n: int | None = None,
            seed: int = 0) -> tuple[int, int, float, bool]:
    """(n, d, avg_nnz, dense) derived from the parsed data (memoized).

    Unlike the synthetic path, the profile comes from what the parser
    actually produced — a truncated or swapped source file shows up
    here (and in :func:`content_hash`) instead of being papered over by
    registry metadata.
    """
    key = (name, split, max_n, seed, raw_digest(name))
    if key not in _profile_memo:
        meta = registry.get(name)
        csr, _ = _parsed(name)
        rows = split_rows(csr.n, split, seed)
        if max_n is not None:
            rows = rows[:max_n]
        sub = csr.select(rows)
        avg = float(meta.d) if meta.dense else sub.avg_nnz
        _profile_memo[key] = (sub.n, meta.d, avg, meta.dense)
    return _profile_memo[key]
