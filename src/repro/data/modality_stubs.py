"""Stub modality frontends (per the assignment: '[audio]/[vlm] entries
specify the transformer BACKBONE only; the modality frontend is a STUB').

These provide shape- and dtype-faithful precomputed embeddings:
  audio : EnCodec frame embeddings  [B, S, d_model]
  vlm   : ViT patch embeddings      [B, 1600, d_model]
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def encodec_frames(rng: np.random.Generator, batch: int, seq: int,
                   d_model: int, dtype=jnp.bfloat16):
    """Stand-in for EnCodec encoder output at 50 Hz frame rate."""
    x = rng.normal(0.0, 1.0, size=(batch, seq, d_model)).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


def vit_patches(rng: np.random.Generator, batch: int, n_patches: int,
                d_model: int, dtype=jnp.bfloat16):
    """Stand-in for a ViT-H/14 vision tower output (1600 patches @ 448px)."""
    x = rng.normal(0.0, 1.0, size=(batch, n_patches, d_model)).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)
