"""Distributed training steps: synchronous and asynchronous-local (the
paper's model-update axis, mapped onto the pod/ICI/DCN hierarchy).

SYNC (paper's synchronous axis)
    Canonical data-parallel mini-batch SGD: batch sharded over
    ("pod", "data"); XLA SPMD inserts the gradient all-reduce.  Statistical
    efficiency is identical to the sequential algorithm (paper Section 4) —
    every chip sees the same model every step.

ASYNC-LOCAL (paper's asynchronous axis; DimmWitted §5.1 at datacenter scale)
    Parameters carry a leading replica axis sharded over "pod": each pod is
    one model replica running independent mini-batch SGD over its data
    shard (gradient all-reduce over "data" *within* the pod only).  Every
    ``merge_every`` steps the replicas are averaged over the pod axis — the
    only traffic that crosses the slow inter-pod DCN boundary.  The merge
    optionally int8-compresses the replica deltas (optim/compress.py).

Virtual axis names in spec trees are resolved here:
    "batch" -> ("pod", "data") present in the mesh
    "seq"   -> "model" when cfg.seq_shard (sequence parallelism) else None
    any axis not in the mesh -> None
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn import transformer
from repro.nn.transformer import ArchConfig
from repro.optim.sgd import Optimizer, apply_updates


# ---------------------------------------------------------------------------
# Spec resolution
# ---------------------------------------------------------------------------


def resolve_spec(spec: P, mesh: Mesh, cfg: ArchConfig | None = None,
                 *, extra: dict | None = None) -> P:
    """Map virtual axis names and drop axes absent from the mesh."""
    names = set(mesh.axis_names)
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    mapping = {"batch": batch_axes,
               "seq": ("model" if (cfg is None or cfg.seq_shard) else None),
               "kvseq": "model"}
    if extra:
        mapping.update(extra)
    def map_one(ax):
        return mapping.get(ax, ax) if isinstance(ax, str) else ax

    out = []
    for ax in spec:
        if isinstance(ax, tuple):  # composite axis: map + flatten + filter
            mapped = []
            for a in ax:
                ma = map_one(a)
                mapped.extend(ma if isinstance(ma, tuple) else (ma,))
            ax = tuple(a for a in mapped if a in names) or None
        else:
            ax = map_one(ax)
            if isinstance(ax, tuple):
                ax = tuple(a for a in ax if a in names) or None
            elif isinstance(ax, str) and ax not in names:
                ax = None
        out.append(ax)
    return P(*out)


def resolve_tree(specs, mesh, cfg=None, *, extra=None):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_spec(s, mesh, cfg, extra=extra)),
        specs, is_leaf=lambda x: isinstance(x, P))


def make_shard_fn(mesh: Mesh | None, cfg: ArchConfig):
    """Activation-constraint callback threaded through the model."""
    if mesh is None:
        return transformer.NOSHARD

    def shard(x, spec):
        s = resolve_spec(spec, mesh, cfg)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))

    return shard


def opt_state_specs(opt_state_shapes, param_specs):
    """Spec tree for optimizer state: moment buffers mirror the params."""
    specs = {}
    for k, v in opt_state_shapes.items():
        specs[k] = param_specs if k in ("m", "v") else P()
    return specs


# ---------------------------------------------------------------------------
# Synchronous step
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepFns:
    """Unjitted step fns + sharding trees (dryrun jits them explicitly)."""
    train_step: Callable
    in_shardings: Any
    out_shardings: Any
    param_shardings: Any
    opt_shardings: Any


def make_sync_step(cfg: ArchConfig, mesh: Mesh, optimizer: Optimizer,
                   param_specs, *, micro_batches: int = 1):
    shard = make_shard_fn(mesh, cfg)

    def loss_of(p, batch):
        return transformer.loss_fn(p, cfg, batch, shard=shard)

    def train_step(params, opt_state, batch):
        if micro_batches == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            def mb(carry, b):
                acc, _ = carry
                l, g = jax.value_and_grad(loss_of)(params, b)
                return (jax.tree.map(jnp.add, acc, g), l), None

            split = jax.tree.map(
                lambda x: x.reshape(micro_batches, x.shape[0] // micro_batches,
                                    *x.shape[1:]), batch)
            zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                 params)
            (gsum, loss), _ = jax.lax.scan(mb, (zeros, jnp.zeros(())), split)
            grads = jax.tree.map(lambda g: g / micro_batches, gsum)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    return train_step


def make_async_local_step(cfg: ArchConfig, mesh: Mesh | None,
                          optimizer: Optimizer, param_specs, *,
                          compress_merge: bool = False):
    """Per-replica local step plus the periodic merge step.

    On a multi-pod mesh the local step is a *partial-auto shard_map*: the
    "pod" axis is manual (each pod runs its own replica with zero cross-pod
    traffic — verified in the HLO: no pod-spanning collectives), while
    data/model parallelism inside the pod stays under automatic SPMD.  The
    earlier vmap-over-replica-axis expression leaked cross-pod all-gathers
    through a reshape (measured +58% wire bytes; EXPERIMENTS.md §Perf).
    Without a mesh (host tests) the vmap path is used.
    """
    pod_manual = mesh is not None and "pod" in mesh.axis_names
    shard = make_shard_fn(mesh, cfg)
    if pod_manual:
        # inside the manual pod axis, "batch" maps to data only
        def shard(x, spec, _mesh=mesh):  # noqa: F811
            s = resolve_spec(spec, _mesh, cfg, extra={"batch": ("data",)})
            return jax.lax.with_sharding_constraint(x, s)

    def loss_of(p, batch):
        return transformer.loss_fn(p, cfg, batch, shard=shard)

    def one_replica(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_of)(params, batch)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    def local_step(params_r, opt_state_r, batch_r):
        """params_r: [R, ...]; batch_r: [R, B/R, ...] — no cross-pod comm."""
        if not pod_manual:
            return jax.vmap(one_replica)(params_r, opt_state_r, batch_r)

        def per_pod(p, o, b):
            squeeze = lambda t: jax.tree.map(lambda x: x[0], t)  # noqa: E731
            p1, o1, loss = one_replica(squeeze(p), squeeze(o), squeeze(b))
            expand = lambda t: jax.tree.map(lambda x: x[None], t)  # noqa
            return expand(p1), expand(o1), loss[None]

        return jax.shard_map(
            per_pod, mesh=mesh,
            in_specs=(P("pod"), P("pod"), P("pod")),
            out_specs=(P("pod"), P("pod"), P("pod")),
            check_vma=False, axis_names={"pod"},
        )(params_r, opt_state_r, batch_r)

    def merge_step(params_r, anchor=None, error_feedback=None):
        """Average replicas over the pod axis (the only DCN traffic).

        With compression: each replica quantizes its drift from the shared
        anchor (int8 + error feedback), the mean of dequantized drifts moves
        the anchor — 4x less cross-pod bytes."""
        if not compress_merge:
            mean = jax.tree.map(
                lambda x: jnp.mean(x.astype(jnp.float32), axis=0,
                                   keepdims=True).astype(x.dtype), params_r)
            merged = jax.tree.map(
                lambda m, x: jnp.broadcast_to(m, x.shape), mean, params_r)
            return merged, anchor, error_feedback

        from repro.optim import compress as C
        delta = jax.tree.map(
            lambda x, a: x.astype(jnp.float32) - a[None].astype(jnp.float32),
            params_r, anchor)
        qt, ef = C.compress_tree(delta, error_feedback)
        deq = C.decompress_tree(qt, delta)
        mean_delta = jax.tree.map(lambda d: jnp.mean(d, axis=0), deq)
        new_anchor = jax.tree.map(
            lambda a, d: (a.astype(jnp.float32) + d).astype(a.dtype),
            anchor, mean_delta)
        merged = jax.tree.map(
            lambda a, x: jnp.broadcast_to(a[None], x.shape).astype(x.dtype),
            new_anchor, params_r)
        return merged, new_anchor, ef

    return local_step, merge_step


# ---------------------------------------------------------------------------
# Serve step
# ---------------------------------------------------------------------------


def make_decode_step(cfg: ArchConfig, mesh: Mesh | None):
    from repro.nn import decode as D
    shard = make_shard_fn(mesh, cfg) if mesh is not None else transformer.NOSHARD

    def serve_step(params, cache, inputs, idx):
        return D.decode_step(params, cfg, cache, inputs, idx, shard=shard)

    return serve_step


def make_prefill_step(cfg: ArchConfig, mesh: Mesh | None):
    shard = make_shard_fn(mesh, cfg) if mesh is not None else transformer.NOSHARD

    def prefill_step(params, inputs):
        h, cache = transformer.forward(params, cfg, inputs, shard=shard,
                                       mode="prefill")
        unembed = params["head"].T if cfg.emb_in() else params["embed"]
        logits = (h[:, -1] @ unembed.T).astype(jnp.float32)
        return logits, cache

    return prefill_step
