"""Fault tolerance: checkpoint/restart, elastic rescale, straggler policy.

What "fault tolerance" means at 1000+ nodes and how this module provides it:

* **Checkpoint/restart** — ``ResilientLoop`` wraps a step function with a
  ``CheckpointManager`` (async, keep-k).  On any step failure the loop
  restores the last checkpoint and replays.  Real-cluster mapping: the
  launcher re-executes the program after a hardware failure; restore-on-start
  is the same code path (``resume=True``).

* **Elastic rescale** — checkpoints are mesh-agnostic (host numpy + manifest;
  checkpoint/checkpoint.py): a state saved on (2,16,16) restores onto
  (16,16) or any other mesh via reshard-on-load.  ``elastic_rescale``
  re-device_puts a live state against a new mesh (shrink after pod loss /
  grow after repair).

* **Straggler mitigation** — the async-local update strategy *is* the
  mitigation (the paper's central insight applied to scheduling): replicas
  never wait for each other between merges, so a straggling pod delays only
  the merge collective, not every step.  ``MergeGate`` additionally skips a
  merge when a replica heartbeat is stale (bounded staleness), which is how
  a dead pod degrades service instead of halting it.

* **Data-pipeline replay** — the loop checkpoints the pipeline epoch/seed so
  restart does not reread examples (deterministic synthetic generators).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


@dataclasses.dataclass
class Heartbeat:
    """Replica liveness bookkeeping (per pod).

    ``clock`` is injectable (monotonic seconds) so staleness tests pin
    time deterministically instead of sleeping — the same discipline as
    ``GLMScoreEngine``'s flush-deadline clock."""

    n_replicas: int
    timeout_s: float = 300.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        self.last_seen = np.full(self.n_replicas, self.clock())

    def beat(self, replica: int):
        self.last_seen[replica] = self.clock()

    def alive(self) -> np.ndarray:
        return (self.clock() - self.last_seen) < self.timeout_s


class MergeGate:
    """Bounded-staleness merge policy for async-local training.

    ``should_merge(step)`` -> merge every K steps; ``alive_mask()`` lets the
    merge average only live replicas (a dead pod is dropped from the mean and
    re-seeded from the merged model when it returns)."""

    def __init__(self, merge_every: int, heartbeat: Heartbeat):
        self.merge_every = merge_every
        self.heartbeat = heartbeat

    def should_merge(self, step: int) -> bool:
        return step > 0 and step % self.merge_every == 0

    def alive_mask(self) -> np.ndarray:
        return self.heartbeat.alive()


@dataclasses.dataclass
class ResilientLoop:
    """Step loop with checkpoint/restart and (simulated) failure injection."""

    step_fn: Callable                    # (state, batch) -> (state, metrics)
    ckpt: CheckpointManager
    state: Any
    resume: bool = True
    max_restore_retries: int = 3
    failure_hook: Callable[[int], bool] | None = None   # tests inject here

    def __post_init__(self):
        self.step = 0
        if self.resume:
            try:
                self.state, self.step = self.ckpt.restore(self.state)
                self.step += 1
            except FileNotFoundError:
                pass

    def run(self, batches, n_steps: int):
        """Returns (final_state, history).  Restores + replays on failure."""
        history = []
        it = iter(batches)
        while self.step < n_steps:
            batch = next(it)
            try:
                if self.failure_hook and self.failure_hook(self.step):
                    raise RuntimeError(f"injected failure @ step {self.step}")
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(jax.tree.leaves(self.state)[0])
            except Exception as e:  # noqa: BLE001 — restart on anything
                restored = False
                for _ in range(self.max_restore_retries):
                    try:
                        self.state, self.step = self.ckpt.restore(self.state)
                        restored = True
                        break
                    except FileNotFoundError:
                        break
                if not restored:
                    raise RuntimeError(
                        f"step {self.step} failed and no checkpoint to "
                        f"restore") from e
                history.append(("restart", self.step, str(e)))
                self.step += 1
                continue
            history.append(("step", self.step, metrics))
            self.ckpt.maybe_save(self.step, self.state)
            self.step += 1
        self.ckpt.wait()
        return self.state, history


def elastic_rescale(state, new_shardings):
    """Re-place a live state onto a new mesh (grow/shrink)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(jax.device_get(x)), s),
        state, new_shardings)
