"""Wall-clock timing helpers for benchmarks (block_until_ready aware)."""
from __future__ import annotations

import time
import statistics
from typing import Callable

import jax


class Timer:
    """Context-manager wall timer (seconds)."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.t0
        return False


def _block(x):
    return jax.block_until_ready(x)


def median_time(fn: Callable, *args, warmup: int = 2, iters: int = 5, **kwargs) -> float:
    """Median wall-clock seconds of ``fn(*args)`` with device sync."""
    for _ in range(warmup):
        _block(fn(*args, **kwargs))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        _block(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    return statistics.median(times)
