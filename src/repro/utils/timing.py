"""Wall-clock timing helpers for benchmarks (block_until_ready aware)."""
from __future__ import annotations

import time
import statistics
from typing import Callable

import jax


class Timer:
    """Context-manager wall timer (seconds)."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.t0
        return False


def _block(x):
    return jax.block_until_ready(x)


def time_stats(fn: Callable, *args, warmup: int = 2, iters: int = 5,
               **kwargs) -> dict:
    """Timing dispersion of ``fn(*args)`` with device sync.

    Returns ``{"median", "min", "mean", "std", "iters"}`` in wall-clock
    seconds — the median is what benchmark snapshots commit (robust to
    one-off stalls); the dispersion fields go to run-varying sidecars
    so noisy hosts are visible in the perf trajectory.
    """
    for _ in range(warmup):
        _block(fn(*args, **kwargs))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        _block(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    return {
        "median": statistics.median(times),
        "min": min(times),
        "mean": statistics.fmean(times),
        "std": statistics.pstdev(times) if len(times) > 1 else 0.0,
        "iters": len(times),
    }


def median_time(fn: Callable, *args, warmup: int = 2, iters: int = 5, **kwargs) -> float:
    """Median wall-clock seconds of ``fn(*args)`` with device sync."""
    return time_stats(fn, *args, warmup=warmup, iters=iters,
                      **kwargs)["median"]
