from repro.utils.tree import (
    tree_add,
    tree_scale,
    tree_mean,
    tree_zeros_like,
    tree_bytes,
    tree_count,
    tree_l2norm,
)
from repro.utils.timing import Timer, median_time

__all__ = [
    "tree_add",
    "tree_scale",
    "tree_mean",
    "tree_zeros_like",
    "tree_bytes",
    "tree_count",
    "tree_l2norm",
    "Timer",
    "median_time",
]
