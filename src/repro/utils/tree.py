"""Pytree helpers used across the framework (no flax/optax in this env)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_mean(trees):
    """Mean of a list of pytrees with identical structure."""
    n = len(trees)
    acc = trees[0]
    for t in trees[1:]:
        acc = tree_add(acc, t)
    return tree_scale(acc, 1.0 / n)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_count(a) -> int:
    """Total number of scalar parameters in the tree."""
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_bytes(a) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(a))


def tree_l2norm(a):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(a)]
    return jnp.sqrt(sum(leaves))


def tree_cast(a, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, a
    )
