"""Trial-cache merge: union per-worker cache roots into the canonical one.

Every worker in a distributed sweep owns a **private** trial-cache root
(one JSON file per ``TrialSpec.key``, written atomically by the study
runner).  After the workers finish — or die — ``merge_caches`` unions
those roots into the canonical cache:

* **idempotent** — a key whose payload bytes already match the
  destination (or an earlier source) is skipped, so re-merging a root,
  merging overlapping roots from a retried shard, or re-running a
  finished sweep is a no-op;
* **conflict-detecting** — the same key with *different* payload bytes
  is never silently resolved.  Trial payloads embed wall-clock epoch
  timings, so two executions of one key never byte-match: a conflict
  means two workers actually computed the same trial (a planner or
  requeue bug) or the canonical cache already held a different result.
  All conflicts are collected and raised together as ``MergeConflict``
  with every conflicting key and the file pair that disagrees.

Payloads are compared as bytes, not parsed JSON: every writer goes
through ``spec.canonical_json`` so equal results are equal bytes, and
byte identity is the invariant CI's sweep-smoke job asserts end-to-end
(merged cache ⇒ byte-identical ``BENCH_study.json``).
"""
from __future__ import annotations

import dataclasses
import os
from pathlib import Path
from typing import Iterable, Sequence


@dataclasses.dataclass(frozen=True)
class Conflict:
    """One same-key/different-payload collision found during a merge."""

    key: str
    ours: Path      # file already merged (destination or earlier source)
    theirs: Path    # file that disagrees

    def __str__(self) -> str:
        return f"{self.key}: {self.ours} != {self.theirs}"


class MergeConflict(RuntimeError):
    """Same trial key, different payload bytes — never auto-resolved."""

    def __init__(self, conflicts: Sequence[Conflict]):
        self.conflicts = tuple(conflicts)
        self.keys = tuple(c.key for c in self.conflicts)
        lines = "\n  ".join(str(c) for c in self.conflicts)
        super().__init__(
            f"{len(self.conflicts)} trial-cache merge conflict(s) "
            f"(same key, different payload):\n  {lines}")


@dataclasses.dataclass
class MergeReport:
    """What one ``merge_caches`` call did."""

    merged: int = 0         # new keys copied into the destination
    identical: int = 0      # keys skipped because the bytes already matched
    sources: int = 0        # source roots scanned

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def cache_entries(root: str | Path) -> list[Path]:
    """The ``<key>.json`` payload files of one cache root (no tmp files).

    The one definition of "completed trial on disk" — the executor's
    dead-worker diagnosis and the merge scan must agree on it.
    """
    root = Path(root)
    if not root.is_dir():
        return []
    return sorted(p for p in root.iterdir()
                  if p.suffix == ".json" and not p.name.startswith("."))


def merge_caches(sources: Iterable[str | Path],
                 dest: str | Path) -> MergeReport:
    """Union per-worker cache roots into ``dest``; raise on conflicts.

    Scans every source (missing/empty roots are fine — a worker that
    died before its first trial has nothing to contribute), validates
    the whole union before writing anything, then copies new keys into
    ``dest`` atomically.  Conflict detection is all-or-nothing: if any
    key disagrees, ``MergeConflict`` lists every collision and ``dest``
    is left untouched.
    """
    dest = Path(dest)
    report = MergeReport()
    chosen: dict[str, tuple[Path, bytes]] = {}
    conflicts: list[Conflict] = []

    for src in sources:
        src = Path(src)
        report.sources += 1
        for path in cache_entries(src):
            key = path.stem
            data = path.read_bytes()
            dest_path = dest / path.name
            if key not in chosen and dest_path.exists():
                chosen[key] = (dest_path, dest_path.read_bytes())
            if key in chosen:
                prev_path, prev = chosen[key]
                if prev == data:
                    report.identical += 1
                else:
                    conflicts.append(Conflict(key, prev_path, path))
                continue
            chosen[key] = (path, data)

    if conflicts:
        raise MergeConflict(conflicts)

    dest.mkdir(parents=True, exist_ok=True)
    for key, (path, data) in sorted(chosen.items()):
        if path.parent == dest:
            continue    # already canonical
        tmp = dest / f".{key}.tmp.{os.getpid()}"
        tmp.write_bytes(data)
        tmp.replace(dest / f"{key}.json")   # atomic on POSIX
        report.merged += 1
    return report
