"""Distributed sweep scheduler: multi-worker ``TrialSpec`` dispatch.

The study subsystem (DESIGN.md §4) executes every trial serially on one
host; this package is the execution layer that spreads a sweep across N
workers while keeping the single-host reproducibility contract: a
distributed sweep fills the *same* canonical trial cache a serial sweep
would, so ``store.StudyStore`` — a pure function of trial results —
writes the same ``BENCH_study.json`` either way (CI's sweep-smoke job
asserts the bytes).

Dataflow (DESIGN.md §6):

    TrialSpec grid ──▶ plan.plan ──▶ N × [worker subprocess] ──▶ merge
      (cache misses)   (stack-aware    (python -m repro.sweep.worker,    │
       from Runner)     sharding)       private cache root each)         ▼
                                                        canonical trial cache
    Runner.run ◀── re-read merged payloads ◀─────────────────────────────┘

Modules
-------
plan      stack-aware deterministic sharding (``plan``, ``Shard``) —
          trials sharing a ``stack_key`` stay co-located so
          vmap-stacking still amortizes compilation
worker    the worker CLI (``python -m repro.sweep.worker``): one shard
          file in, one private trial cache out, durable per stack group
executor  the executor interface + ``LocalProcessExecutor``
          (subprocess dispatch, bounded retries, dead-worker requeue)
merge     ``merge_caches``: idempotent cache union with
          same-key/different-payload conflict detection

Quickstart — distribute any sweep by attaching an executor::

    from repro.study.runner import Runner
    from repro.sweep import LocalProcessExecutor

    runner = Runner(cache_dir="bench_results/study_cache",
                    executor=LocalProcessExecutor(workers=2))
    runner.run(trials)      # cache misses dispatched across 2 workers

``python -m benchmarks.run --workers N`` wires this into the full
table/figure sweeps; docs/SWEEPS.md is the usage guide.
"""
from repro.sweep.executor import (ExecReport, LocalProcessExecutor,  # noqa: F401
                                  ShardFailure, ShardRun)
from repro.sweep.merge import (Conflict, MergeConflict, MergeReport,  # noqa: F401
                               merge_caches)
from repro.sweep.plan import Shard, plan  # noqa: F401
