"""Stack-aware sweep planner: shard a ``TrialSpec`` grid across N workers.

The planner's one hard invariant is **stack-group co-location**: trials
that share a ``stack_key`` (same spec up to the step size — the §6.1
grid) must land on the same worker, because the runner executes such a
group as one vmap-stacked compiled program.  Splitting a group would
both forfeit the compilation amortization and change the recorded
timing meta (``stacked`` amortizes wall time 1/S over the group), so a
distributed sweep would stop reproducing the single-host cache.

Within that constraint the planner balances load with a deterministic
longest-processing-time greedy: groups are weighted by an
epochs × examples × nnz-per-example work proxy from the dataset
profile (so one full-size dataset group outweighs many fixture-sized
ones), sorted heaviest-first (ties broken on ``stack_key``), and each
is assigned to the least-loaded worker (ties broken on the lowest
worker index).  Same trial list + same worker count ⇒ same plan,
everywhere — the scheduler's requeue logic and the provenance log rely
on that.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.study.spec import SCHEMA_VERSION, TrialSpec


@dataclasses.dataclass(frozen=True)
class Shard:
    """One worker's slice of the sweep: whole stack groups only."""

    worker: int
    trials: tuple[TrialSpec, ...]

    @property
    def keys(self) -> tuple[str, ...]:
        return tuple(t.key for t in self.trials)

    def to_dict(self) -> dict:
        """The on-disk shard file consumed by ``repro.sweep.worker``."""
        return {
            "schema": SCHEMA_VERSION,
            "worker": self.worker,
            "trials": [t.to_dict() for t in self.trials],
        }

    @classmethod
    def from_dict(cls, dct: dict) -> "Shard":
        if dct.get("schema") != SCHEMA_VERSION:
            raise ValueError(
                f"shard schema {dct.get('schema')!r} != {SCHEMA_VERSION}")
        return cls(worker=dct["worker"],
                   trials=tuple(TrialSpec.from_dict(d)
                                for d in dct["trials"]))


def _group_weight(group: Sequence[TrialSpec], profiles: dict) -> float:
    """Work proxy for one stack group: epochs × examples × nnz/example.

    A stacked group runs as one fused program, so its wall cost scales
    with the per-epoch data volume and the epoch count, not with the
    member count S; ``+ S`` keeps big grids from ever weighing zero.
    The dataset profile is derivable without materializing the data
    and is what separates a full-size dataset group from many
    fixture-sized ones — strategy constants are deliberately ignored
    (a proxy, not the advisor's cost model).
    """
    t = group[0]
    if t.dataset not in profiles:
        profiles[t.dataset] = t.dataset.profile()
    prof = profiles[t.dataset]
    return t.epochs * prof.n * prof.nnz_per_example + len(group)


def plan(trials: Sequence[TrialSpec], workers: int) -> list[Shard]:
    """Shard ``trials`` over ``workers``, co-locating stack groups.

    Duplicate specs (same ``key``) are dispatched once.  Returns only
    non-empty shards (fewer groups than workers ⇒ fewer shards), with
    each shard's trials in their original input order.
    """
    if workers < 1:
        raise ValueError(f"workers must be >= 1: {workers}")
    groups: dict[str, list[TrialSpec]] = {}
    pos: dict[str, int] = {}
    for i, t in enumerate(trials):
        if t.key in pos:
            continue
        pos[t.key] = i
        groups.setdefault(t.stack_key, []).append(t)

    profiles: dict = {}
    weight = {sk: _group_weight(g, profiles) for sk, g in groups.items()}
    order = sorted(groups, key=lambda sk: (-weight[sk], sk))
    loads = [0.0] * workers
    assigned: list[list[TrialSpec]] = [[] for _ in range(workers)]
    for sk in order:
        w = min(range(workers), key=lambda i: (loads[i], i))
        loads[w] += weight[sk]
        assigned[w].extend(groups[sk])

    # restore input order inside each shard (stacking regroups by key anyway,
    # but stable order keeps shard files and provenance logs reproducible)
    return [
        Shard(worker=w, trials=tuple(sorted(ts, key=lambda t: pos[t.key])))
        for w, ts in enumerate(assigned) if ts
    ]
