"""Sweep worker: execute one shard file into a private trial cache.

    python -m repro.sweep.worker --shard SHARD.json --cache-dir DIR
        [--no-stack] [--fault-after N [--fault-flag PATH]]

The worker is the unit of fault isolation in a distributed sweep: it
reads a shard (serialized ``TrialSpec``s, written by the scheduler),
executes it stack-group by stack-group through a plain ``study.Runner``
whose cache root is **private to this worker**, and exits 0.  Every
completed trial is already durably cached when the next group starts
(the runner's one-file-per-key atomic writes), so a worker killed
mid-shard leaves a valid partial cache behind — the executor requeues
exactly the keys missing from it and merges whatever did land.

Progress is reported one JSON line per completed stack group on stdout
(``{"done": k, "of": n, "keys": [...]}``); the executor treats stdout
as a log, not a protocol — the cache directory is the source of truth.

``--fault-after N`` is the test/debug hook for the fault-tolerance
path: after N completed trials the worker exits with status 17 —
once, if ``--fault-flag PATH`` names a sentinel file (created on the
first trip, so the retried shard runs to completion), or on every
attempt without it (exercises retry exhaustion).  ``--fault-mode kill``
makes the fault a real ``SIGKILL`` (no atexit, no cleanup) instead of
``sys.exit``: the durability story — per-group ``metrics.flush()`` and
the runner's atomic cache writes — is what keeps the partial sidecar
and cache readable, and the tests assert exactly that.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys
from pathlib import Path

from repro.obs import metrics, trace
from repro.study.runner import Runner
from repro.sweep.plan import Shard

#: exit status of an injected fault (distinct from argparse's 2 / crash's 1)
FAULT_EXIT = 17


def _maybe_fault(done: int, fault_after: int | None,
                 fault_flag: str | None, fault_mode: str = "exit") -> None:
    if fault_after is None or done < fault_after:
        return
    if fault_flag is not None:
        flag = Path(fault_flag)
        if flag.exists():
            return      # already tripped once; run normally this attempt
        flag.parent.mkdir(parents=True, exist_ok=True)
        flag.write_text("tripped\n")
    print(json.dumps({"fault_injected_after": done}), flush=True)
    if fault_mode == "kill":
        # the real thing: no atexit flush, no unwinding — only the
        # per-group flushes already on disk survive
        os.kill(os.getpid(), signal.SIGKILL)
    sys.exit(FAULT_EXIT)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.sweep.worker",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--shard", required=True,
                    help="shard file written by the sweep planner")
    ap.add_argument("--cache-dir", required=True,
                    help="this worker's PRIVATE trial-cache root")
    ap.add_argument("--no-stack", action="store_true",
                    help="disable vmap step-stacking (debug)")
    ap.add_argument("--fault-after", type=int, default=None,
                    help="test hook: exit(17) after N completed trials")
    ap.add_argument("--fault-flag", default=None,
                    help="sentinel file making --fault-after a one-shot")
    ap.add_argument("--fault-mode", choices=("exit", "kill"), default="exit",
                    help="fault flavor: clean exit(17), or SIGKILL self "
                         "(tests sidecar/cache durability)")
    args = ap.parse_args(argv)

    with open(args.shard) as f:
        shard = Shard.from_dict(json.load(f))
    runner = Runner(cache_dir=args.cache_dir, stack=not args.no_stack)

    groups: dict[str, list] = {}
    for t in shard.trials:
        groups.setdefault(t.stack_key, []).append(t)

    done = 0
    total = len(shard.trials)
    # the executor sets REPRO_TRACE_TAG=shard<W>a<A> per attempt, so this
    # span lands in a per-attempt trace file the report CLI stitches into
    # the driver's timeline
    with trace.span("sweep.shard", worker=shard.worker, trials=total,
                    groups=len(groups)):
        _maybe_fault(done, args.fault_after, args.fault_flag, args.fault_mode)
        for group in groups.values():
            with trace.span("sweep.group", stack_key=group[0].stack_key,
                            trials=len(group)):
                runner.run(group)
            done += len(group)
            print(json.dumps({"done": done, "of": total,
                              "keys": [t.key for t in group]}), flush=True)
            # durability point: everything this group counted is on disk
            # before a fault (even SIGKILL) can take the process down
            metrics.flush(0)
            _maybe_fault(done, args.fault_after, args.fault_flag,
                         args.fault_mode)
    return 0


if __name__ == "__main__":
    sys.exit(main())
