"""Sweep executors: dispatch planned shards to workers and merge caches.

The executor contract (what ``study.Runner`` calls when it has
cache-miss trials and an executor attached) is a single method::

    execute(trials, cache, *, stack=True) -> ExecReport

which must leave every trial's payload in ``cache.root`` (the canonical
trial-cache directory) and report what ran where.  The interface is
deliberately this small so a later multi-process-JAX / mesh backend —
one worker per host of a TPU pod, dispatch over ``jax.distributed`` —
slots in behind the same method; ``LocalProcessExecutor`` is the
subprocess-based local implementation shipped here.

``LocalProcessExecutor`` lifecycle per ``execute`` call:

1. **plan** — ``sweep.plan.plan`` shards the trials stack-aware
   (stack groups are never split across workers);
2. **dispatch** — one ``python -m repro.sweep.worker`` subprocess per
   shard, all concurrently, each with a *private* cache root and shard
   file under a per-call scratch directory; workers inherit this
   process's environment (plus ``PYTHONPATH`` pinned to this repro
   package) so dataset sources and backend overrides carry over;
3. **fault tolerance** — a worker that exits non-zero (or is killed)
   is diagnosed from its private cache: completed keys stay, the
   missing ones are requeued as a new shard attempt, up to
   ``max_retries`` requeues.  Exhausted retries never abandon sibling
   workers mid-flight: every live worker is waited for and every
   private root is merged *before* ``ShardFailure`` surfaces the
   worker log — so even a failed sweep preserves all completed trials
   in the canonical cache and resumes instead of recomputing;
4. **merge** — ``merge_caches`` unions every private root (including
   the partial roots of dead workers) into the canonical cache, with
   same-key/different-payload conflict detection, then the executor
   verifies every requested key is present.  The per-call scratch
   directory (shard files, private caches, logs) is deleted after a
   fully successful merge and kept for post-mortem on any failure.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time
from pathlib import Path
from typing import Sequence

from repro.obs import metrics, trace
from repro.study.runner import TrialCache
from repro.study.spec import TrialSpec
from repro.sweep import plan as plan_mod
from repro.sweep.merge import MergeReport, cache_entries, merge_caches


class ShardFailure(RuntimeError):
    """A shard still had unfinished trials after the retry budget.

    Carries the ``ExecReport`` built up to the failure (``report``) so
    the caller can still log worker/shard/merge provenance — a failed
    sweep is exactly when attribution matters most.
    """

    def __init__(self, message: str, report: "ExecReport | None" = None):
        super().__init__(message)
        self.report = report


@dataclasses.dataclass(frozen=True)
class ShardRun:
    """Provenance of one worker attempt (recorded in the store's JSONL)."""

    worker: int
    attempt: int
    returncode: int
    wall_s: float
    keys: tuple[str, ...]           # what the attempt was asked to run
    completed: tuple[str, ...]      # what landed in its private cache
    requeued: tuple[str, ...]       # what the scheduler re-dispatched
    trace_file: str | None = None   # the attempt's trace (REPRO_TRACE=1)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ExecReport:
    """Everything one ``execute`` call did, for logs and provenance."""

    executor: str
    workers: int
    n_trials: int
    shard_runs: list[ShardRun]
    merge: MergeReport

    @property
    def retries(self) -> int:
        return sum(1 for r in self.shard_runs if r.attempt > 0)


def _worker_env() -> dict:
    """Child env: inherit everything, pin PYTHONPATH to this package."""
    import repro
    # repro is a namespace package: locate its parent via __path__
    src = str(Path(next(iter(repro.__path__))).resolve().parent)
    env = dict(os.environ)
    paths = [src] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
                     if p and p != src]
    env["PYTHONPATH"] = os.pathsep.join(paths)
    return env


def _log_tail(path: Path, n: int = 20) -> str:
    try:
        return "\n".join(path.read_text().splitlines()[-n:])
    except OSError:
        return "<no worker log>"


class LocalProcessExecutor:
    """Run shards as local worker subprocesses with bounded retries.

    ``worker_args`` is passed through to every worker invocation — the
    fault-injection hooks (``--fault-after`` / ``--fault-flag``) and
    ``--no-stack`` ride on it; production sweeps leave it empty.
    """

    kind = "local-process"

    def __init__(self, workers: int, *, work_dir: str | Path | None = None,
                 max_retries: int = 2,
                 worker_args: Sequence[str] = ()):
        if workers < 1:
            raise ValueError(f"workers must be >= 1: {workers}")
        self.workers = workers
        self.work_dir = Path(work_dir) if work_dir is not None else None
        self.max_retries = max_retries
        self.worker_args = tuple(worker_args)

    # -- dispatch ------------------------------------------------------------

    def _launch(self, shard: plan_mod.Shard, attempt: int, run_dir: Path,
                env: dict, *, stack: bool) -> dict:
        tag = f"w{shard.worker}a{attempt}"
        root = run_dir / f"cache-{tag}"
        shard_path = run_dir / f"shard-{tag}.json"
        log_path = run_dir / f"worker-{tag}.log"
        shard_path.write_text(json.dumps(shard.to_dict()))
        cmd = [sys.executable, "-m", "repro.sweep.worker",
               "--shard", str(shard_path), "--cache-dir", str(root),
               *(() if stack else ("--no-stack",)),
               *self.worker_args]
        trace_file = None
        if trace.enabled() or metrics.enabled():
            # each attempt gets its own tag → its own trace/metrics files,
            # so a requeued shard shows up as an extra lane in the merged
            # view (metrics-only mode still needs the tag for its sidecar)
            env = dict(env)
            env[trace.ENV_TRACE_TAG] = f"shard{shard.worker}a{attempt}"
        log = open(log_path, "w")
        proc = subprocess.Popen(cmd, stdout=log, stderr=subprocess.STDOUT,
                                env=env)
        if trace.enabled():
            trace_file = str(trace.trace_path(
                trace.current_dir(), env[trace.ENV_TRACE_TAG], proc.pid))
        return {"shard": shard, "attempt": attempt, "root": root,
                "proc": proc, "log": log, "log_path": log_path,
                "trace_file": trace_file, "t0": time.perf_counter()}

    def execute(self, trials: Sequence[TrialSpec], cache: TrialCache, *,
                stack: bool = True) -> ExecReport:
        if cache.root is None:
            raise ValueError("distributed sweeps need a canonical cache root")
        if self.work_dir is not None:
            self.work_dir.mkdir(parents=True, exist_ok=True)
        run_dir = Path(tempfile.mkdtemp(
            prefix="sweep-", dir=self.work_dir))
        env = _worker_env()

        shards = plan_mod.plan(trials, self.workers)
        queue: list[tuple[plan_mod.Shard, int]] = [(s, 0) for s in shards]
        shard_runs: list[ShardRun] = []
        roots: list[Path] = []
        failures: list[str] = []
        live: list[dict] = []

        with trace.span("sweep.execute", workers=self.workers,
                        shards=len(shards), trials=len(trials)):
            try:
                while queue:
                    live = []
                    for s, a in queue:  # loop, not a comprehension: a launch
                        live.append(    # failure must not lose live handles
                            self._launch(s, a, run_dir, env, stack=stack))
                    queue = []
                    # reap every live worker before deciding anything (an
                    # exhausted shard must not orphan its siblings
                    # mid-compute), polling so each worker's wall time is
                    # its own exit time, not the round's slowest — the
                    # provenance events attribute wall time per worker
                    t_exit: dict[int, float] = {}
                    while len(t_exit) < len(live):
                        progressed = False
                        for i, item in enumerate(live):
                            if i not in t_exit \
                                    and item["proc"].poll() is not None:
                                t_exit[i] = time.perf_counter()
                                progressed = True
                        if not progressed:
                            time.sleep(0.02)
                    for i, item in enumerate(live):
                        rc = item["proc"].returncode
                        item["log"].close()
                        wall = t_exit[i] - item["t0"]
                        shard, attempt, root = (item["shard"],
                                                item["attempt"],
                                                item["root"])
                        roots.append(root)
                        done = {p.stem for p in cache_entries(root)}
                        unfinished = tuple(t for t in shard.trials
                                           if t.key not in done)
                        requeued: tuple[str, ...] = ()
                        if rc != 0 and unfinished:
                            if attempt >= self.max_retries:
                                tf = item["trace_file"]
                                failures.append(
                                    f"worker {shard.worker} failed "
                                    f"{attempt + 1}x (exit {rc}), "
                                    f"{len(unfinished)} trial(s) unfinished;"
                                    + (f" trace: {tf};" if tf else "")
                                    + f" last log lines:\n"
                                    f"{_log_tail(item['log_path'])}")
                            else:
                                requeue = plan_mod.Shard(
                                    worker=shard.worker, trials=unfinished)
                                queue.append((requeue, attempt + 1))
                                requeued = requeue.keys
                                metrics.counter("sweep.requeue").inc()
                        shard_runs.append(ShardRun(
                            worker=shard.worker, attempt=attempt,
                            returncode=rc, wall_s=wall, keys=shard.keys,
                            completed=tuple(t.key for t in shard.trials
                                            if t.key in done),
                            requeued=requeued,
                            trace_file=item["trace_file"]))
            finally:
                # interrupted mid-round (Ctrl-C, launch failure): never
                # leave worker subprocesses running or log handles open
                for item in live:
                    if item["proc"].poll() is None:
                        item["proc"].terminate()
                        try:
                            item["proc"].wait(timeout=5)
                        except subprocess.TimeoutExpired:
                            item["proc"].kill()
                            item["proc"].wait()
                    if not item["log"].closed:
                        item["log"].close()

        # merge BEFORE raising: even a failed sweep keeps every completed
        # trial, so the next attempt resumes instead of recomputing
        with trace.span("sweep.merge", roots=len(roots)):
            merge = merge_caches(roots, cache.root)
        report = ExecReport(executor=self.kind, workers=self.workers,
                            n_trials=len(trials), shard_runs=shard_runs,
                            merge=merge)
        if failures:
            raise ShardFailure(
                "\n".join(failures)
                + f"\n(completed trials were merged into {cache.root}; "
                f"scratch kept at {run_dir})", report)
        missing = [t.key for t in trials
                   if not (Path(cache.root) / f"{t.key}.json").exists()]
        if missing:
            raise ShardFailure(
                f"{len(missing)} trial(s) missing from the merged cache "
                f"despite clean worker exits: {missing[:5]} "
                f"(scratch kept at {run_dir})", report)
        shutil.rmtree(run_dir, ignore_errors=True)
        return report
