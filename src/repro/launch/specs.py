"""ShapeDtypeStruct input specs for every (arch × shape) dry-run cell.

Nothing here allocates: params/optimizer/cache shapes come from
``jax.eval_shape`` over the real init functions (the spec trees are stashed
via closure during tracing), and batch inputs are ShapeDtypeStructs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.nn import decode as decode_mod
from repro.nn import transformer
from repro.nn.transformer import ArchConfig


def param_shapes_and_specs(cfg: ArchConfig):
    box = {}

    def f(key):
        p, s = transformer.init_params(cfg, key)
        box["specs"] = s
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["specs"]


def cache_shapes_and_specs(cfg: ArchConfig, batch: int, max_len: int):
    box = {}

    def f():
        c, s = decode_mod.init_cache(cfg, batch, max_len)
        box["specs"] = s
        return c

    shapes = jax.eval_shape(f)
    return shapes, box["specs"]


def batch_specs(cfg: ArchConfig, kind: str, seq: int, gb: int):
    """(ShapeDtypeStruct tree, PartitionSpec tree) for the step inputs."""
    i32, bf16 = jnp.int32, cfg.param_dtype
    shapes, specs = {}, {}
    s = 1 if kind == "decode" else seq
    if cfg.emb_in():
        shapes["embeddings"] = jax.ShapeDtypeStruct((gb, s, cfg.d_model), bf16)
        specs["embeddings"] = P("batch", None, None)
    else:
        shapes["tokens"] = jax.ShapeDtypeStruct((gb, s), i32)
        specs["tokens"] = P("batch", None)
    if cfg.family == "vlm":
        shapes["memory"] = jax.ShapeDtypeStruct((gb, cfg.n_memory, cfg.d_model),
                                                bf16)
        specs["memory"] = P("batch", None, None)
    if kind == "train":
        shapes["labels"] = jax.ShapeDtypeStruct((gb, seq), i32)
        specs["labels"] = P("batch", None)
    return shapes, specs


def input_specs(arch: str, shape: str):
    """Everything dryrun needs for one cell (shape structs + spec trees)."""
    cfg = configs.get(arch)
    kind, seq, gb = configs.SHAPES[shape]
    p_shapes, p_specs = param_shapes_and_specs(cfg)
    b_shapes, b_specs = batch_specs(cfg, kind, seq, gb)
    out = dict(cfg=cfg, kind=kind, seq=seq, gb=gb,
               params=(p_shapes, p_specs), batch=(b_shapes, b_specs))
    if kind == "decode":
        out["cache"] = cache_shapes_and_specs(cfg, gb, seq)
    return out
