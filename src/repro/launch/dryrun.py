import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count on first init.  512 placeholder host devices back the production
# meshes; nothing else in the repo sets this flag (tests/benches see 1 dev).

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
single-pod (16,16) and multi-pod (2,16,16) meshes; record memory analysis,
cost analysis and gzipped post-SPMD HLO for the roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all          # full sweep
    PYTHONPATH=src python -m repro.launch.dryrun --all --multipod-only

Each cell runs in-process; ``--all`` spawns one subprocess per cell so a
compiler OOM/fault cannot kill the sweep (fault isolation, like the real
launcher).  Results land in dryrun_results/<arch>__<shape>__<mesh>.json.
"""
import argparse
import gzip
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

RESULTS_DIR = Path(__file__).resolve().parents[3] / "dryrun_results"


def run_cell(arch: str, shape: str, multi_pod: bool, update: str = "sync",
             save_hlo: bool = True) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding

    from repro import configs
    from repro.launch import mesh as mesh_mod
    from repro.launch import specs as specs_mod
    from repro.optim.sgd import sgd as make_sgd
    from repro.train import trainer

    t0 = time.time()
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    cell = specs_mod.input_specs(arch, shape)
    cfg, kind = cell["cfg"], cell["kind"]
    if cfg.moe_experts:
        # group-local MoE dispatch: one group per batch shard
        import dataclasses as _dc
        n_batch_shards = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                n_batch_shards *= mesh.shape[a]
        cfg = _dc.replace(cfg, moe_groups=min(n_batch_shards, cell["gb"]),
                          moe_model_shards=mesh.shape["model"])
        cell["cfg"] = cfg
        cell["params"] = specs_mod.param_shapes_and_specs(cfg)
    p_shapes, p_specs = cell["params"]
    b_shapes, b_specs = cell["batch"]

    # when the global batch cannot shard over the batch axes (long_500k has
    # B=1), replicate the batch and spread KV caches over the whole mesh
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch_prod = 1
    for a in batch_axes:
        batch_prod *= mesh.shape[a]
    cell_extra = None
    if cell["gb"] % batch_prod:
        cell_extra = {"batch": (), "kvseq": (*batch_axes, "model")}

    def shardings(specs, extra=None):
        ex = dict(cell_extra or {})
        ex.update(extra or {})
        return trainer.resolve_tree(specs, mesh, cfg, extra=ex or None)

    with mesh:
        if kind == "train":
            # plain SGD: the paper's optimizer (momentum costs another
            # param-sized buffer; kimi-scale memory notes in EXPERIMENTS.md)
            opt = make_sgd(1e-2)
            o_shapes = jax.eval_shape(opt.init, p_shapes)
            o_specs = trainer.opt_state_specs(o_shapes, p_specs)

            if update == "sync":
                step = trainer.make_sync_step(cfg, mesh, opt, p_specs)
                in_sh = (shardings(p_specs), shardings(o_specs),
                         shardings(b_specs))
                out_sh = (shardings(p_specs), shardings(o_specs),
                          NamedSharding(mesh, P()))
                args = (p_shapes, o_shapes, b_shapes)
            else:  # async-local: replica axis over "pod"
                assert multi_pod, "async-local needs the pod axis"
                R = mesh.shape["pod"]
                local, merge = trainer.make_async_local_step(
                    cfg, mesh, opt, p_specs)
                stack = lambda t: jax.tree.map(  # noqa: E731
                    lambda x: jax.ShapeDtypeStruct((R, *x.shape), x.dtype), t)
                rep = {"batch": ("data",)}  # replica batch: data axis only
                pod_specs = jax.tree.map(
                    lambda s: P("pod", *s), p_specs,
                    is_leaf=lambda x: isinstance(x, P))
                pod_o_specs = trainer.opt_state_specs(o_shapes, pod_specs)
                pod_o_specs["step"] = P("pod")  # per-replica counter [R]
                b_specs_r = jax.tree.map(
                    lambda s: P("pod", *s), b_specs,
                    is_leaf=lambda x: isinstance(x, P))
                b_shapes_r = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(
                        (R, x.shape[0] // R, *x.shape[1:]), x.dtype), b_shapes)
                step = local
                in_sh = (shardings(pod_specs, extra=rep),
                         shardings(pod_o_specs, extra=rep),
                         shardings(b_specs_r, extra=rep))
                out_sh = (shardings(pod_specs, extra=rep),
                          shardings(pod_o_specs, extra=rep),
                          NamedSharding(mesh, P("pod")))
                args = (stack(p_shapes),
                        jax.eval_shape(lambda p: jax.vmap(opt.init)(p),
                                       stack(p_shapes)),
                        b_shapes_r)

            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)

        elif kind == "prefill":
            step = trainer.make_prefill_step(cfg, mesh)
            c_shapes, c_specs = specs_mod.cache_shapes_and_specs(
                cfg, cell["gb"], cell["seq"])
            in_sh = (shardings(p_specs), shardings(b_specs))
            out_sh = (NamedSharding(mesh, P()), shardings(c_specs))
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(p_shapes, b_shapes)

        else:  # decode
            step = trainer.make_decode_step(cfg, mesh)
            c_shapes, c_specs = cell["cache"]
            in_sh = (shardings(p_specs), shardings(c_specs),
                     shardings(b_specs), NamedSharding(mesh, P()))
            out_sh = (NamedSharding(mesh, P()), shardings(c_specs))
            idx = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(
                p_shapes, c_shapes, b_shapes, idx)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    from repro.roofline import hlo as hlo_mod

    mem = compiled.memory_analysis()
    cost = hlo_mod.cost_analysis_dict(compiled)  # list-vs-dict jax drift
    result = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "update": update, "kind": kind,
        "seq": cell["seq"], "global_batch": cell["gb"],
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost_analysis": {k: cost[k] for k in
                          ("flops", "bytes accessed", "transcendentals")
                          if k in cost},
        "status": "ok",
    }
    if save_hlo:
        RESULTS_DIR.mkdir(exist_ok=True)
        hlo_path = RESULTS_DIR / _cell_name(arch, shape, multi_pod, update,
                                            ext=".hlo.gz")
        with gzip.open(hlo_path, "wt") as f:
            f.write(compiled.as_text())
        result["hlo_file"] = str(hlo_path)
    return result


def _cell_name(arch, shape, multi_pod, update="sync", ext=".json"):
    mesh = "2x16x16" if multi_pod else "16x16"
    upd = "" if update == "sync" else f"__{update}"
    return f"{arch}__{shape}__{mesh}{upd}{ext}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--update", default="sync", choices=["sync", "async"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multipod-only", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if not args.all:
        res = run_cell(args.arch, args.shape, args.multipod, args.update)
        RESULTS_DIR.mkdir(exist_ok=True)
        out = RESULTS_DIR / _cell_name(args.arch, args.shape, args.multipod,
                                       args.update)
        out.write_text(json.dumps(res, indent=1))
        print(json.dumps({k: v for k, v in res.items() if k != "hlo_file"}))
        return

    # sweep: one subprocess per cell (fault isolation)
    from repro import configs
    RESULTS_DIR.mkdir(exist_ok=True)
    meshes = []
    if not args.multipod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)
    cells = [(a, s, mp) for mp in meshes for (a, s) in configs.cells()]
    failures = []
    for arch, shape, mp in cells:
        out = RESULTS_DIR / _cell_name(arch, shape, mp)
        if out.exists() and not args.force:
            print(f"skip {out.name}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape] + (
                   ["--multipod"] if mp else [])
        print(f"=== {arch} {shape} {'2x16x16' if mp else '16x16'}",
              flush=True)
        t0 = time.time()
        r = subprocess.run(cmd, capture_output=True, text=True)
        if r.returncode != 0:
            failures.append((arch, shape, mp))
            out.write_text(json.dumps({
                "arch": arch, "shape": shape,
                "mesh": "2x16x16" if mp else "16x16",
                "status": "fail", "stderr": r.stderr[-4000:],
            }, indent=1))
            print(f"    FAIL ({time.time()-t0:.0f}s): "
                  f"{r.stderr.strip().splitlines()[-1] if r.stderr else '?'}")
        else:
            print(f"    ok ({time.time()-t0:.0f}s)")
    print(f"done; {len(failures)} failures: {failures}")


if __name__ == "__main__":
    main()
