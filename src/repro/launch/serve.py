"""Serving driver: batched requests against a (reduced or full) arch.

    PYTHONPATH=src python -m repro.launch.serve --arch minitron-4b --smoke \
        --requests 8 --slots 4 --max-new 16

``--smoke`` serves the reduced config on host devices; the full config path
expects a checkpoint directory (--ckpt) produced by launch/train.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint import load_checkpoint
from repro.nn import transformer
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = configs.reduced(cfg)
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt:
        params, step = load_checkpoint(args.ckpt, params)
        print(f"restored checkpoint step {step}")

    engine = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len,
                         temperature=args.temperature, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(i, rng.integers(0, cfg.vocab, size=int(rng.integers(2, 9))),
                    max_new=args.max_new) for i in range(args.requests)]

    t0 = time.time()
    done = engine.run(reqs, max_ticks=4000)
    dt = time.time() - t0
    tokens = sum(len(r.out) for r in done)
    print(f"{len(done)}/{len(reqs)} requests; {tokens} tokens in {dt:.1f}s "
          f"({tokens/max(dt,1e-9):.1f} tok/s on {args.slots} slots)")
    assert len(done) == len(reqs), "engine failed to drain the queue"
    return done


if __name__ == "__main__":
    main()
