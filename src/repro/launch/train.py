"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch minitron-4b \
        --smoke --steps 50 --update async --merge-every 5

``--smoke`` trains the reduced same-family config on host devices (the CPU
container path); without it the full config is used (real-cluster path —
the mesh must exist).  Supports sync and async-local update strategies,
checkpoint/restart and failure injection (--inject-failure-at).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import synthetic
from repro.data.pipeline import TokenPipeline
from repro.nn import transformer
from repro.optim.sgd import sgd as make_sgd, sgd_momentum
from repro.optim.adam import adam as make_adam
from repro.train import trainer, fault


def make_batch_fn(cfg, gb, seq, seed=0, fixed: bool = False):
    """``fixed=True`` repeats one batch — smoke runs overfit it, which is
    the honest convergence check on synthetic data (fresh random tokens
    have no learnable structure beyond the marginal)."""
    rng = np.random.default_rng(seed)

    def one():
        ins = {}
        if cfg.emb_in():
            ins["embeddings"] = jnp.asarray(rng.normal(
                0, 1, (gb, seq, cfg.d_model)).astype(np.float32),
                dtype=cfg.param_dtype)
        else:
            ins["tokens"] = jnp.asarray(
                rng.integers(0, cfg.vocab, (gb, seq)), dtype=jnp.int32)
        if cfg.family == "vlm":
            ins["memory"] = jnp.asarray(rng.normal(
                0, 1, (gb, cfg.n_memory, cfg.d_model)).astype(np.float32),
                dtype=cfg.param_dtype)
        ins["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (gb, seq)), dtype=jnp.int32)
        return ins

    def gen():
        first = one()
        while True:
            yield first if fixed else one()

    return gen()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--optimizer", default="sgd",
                    choices=["sgd", "momentum", "adam"])
    ap.add_argument("--update", default="sync", choices=["sync", "async"])
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--merge-every", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--inject-failure-at", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = configs.reduced(cfg)
    opt = {"sgd": lambda: make_sgd(args.lr),
           "momentum": lambda: sgd_momentum(args.lr),
           "adam": lambda: make_adam(args.lr)}[args.optimizer]()

    params, specs = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batches = make_batch_fn(cfg, args.batch, args.seq, fixed=args.smoke)

    if args.update == "sync":
        # host run: no mesh; sharding constraints are no-ops
        def loss_of(p, b):
            return transformer.loss_fn(p, cfg, b)

        @jax.jit
        def step(state, batch):
            p, o = state
            loss, grads = jax.value_and_grad(loss_of)(p, batch)
            updates, o = opt.update(grads, o, p)
            from repro.optim.sgd import apply_updates
            return (apply_updates(p, updates), o), {"loss": loss}

        state = (params, opt.init(params))
    else:
        R = args.replicas
        from repro.optim.sgd import apply_updates

        def loss_of(p, b):
            return transformer.loss_fn(p, cfg, b)

        def one(p, o, b):
            loss, grads = jax.value_and_grad(loss_of)(p, b)
            updates, o = opt.update(grads, o, p)
            return apply_updates(p, updates), o, loss

        me = args.merge_every

        @jax.jit
        def step(state, batch):
            p, o, t = state
            bs = jax.tree.map(
                lambda x: x.reshape(R, x.shape[0] // R, *x.shape[1:]), batch)
            p, o, loss = jax.vmap(one)(p, o, bs)
            do_merge = (t + 1) % me == 0
            p = jax.lax.cond(
                do_merge,
                lambda q: jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        jnp.mean(x.astype(jnp.float32), 0, keepdims=True
                                 ).astype(x.dtype), x.shape), q),
                lambda q: q, p)
            return (p, o, t + 1), {"loss": jnp.mean(loss)}

        stack = lambda t: jax.tree.map(  # noqa: E731
            lambda x: jnp.broadcast_to(x[None], (R, *x.shape)), t)
        state = (stack(params), jax.vmap(opt.init)(stack(params)),
                 jnp.zeros((), jnp.int32))

    ckpt = CheckpointManager(args.ckpt_dir or "/tmp/repro_ckpt",
                             every=args.ckpt_every)
    failure = None
    if args.inject_failure_at is not None:
        fired = {"done": False}

        def failure(step_i):
            if step_i == args.inject_failure_at and not fired["done"]:
                fired["done"] = True
                return True
            return False

    loop = fault.ResilientLoop(step, ckpt, state, resume=False,
                               failure_hook=failure)
    t0 = time.time()
    _, history = loop.run(batches, args.steps)
    steps = [h for h in history if h[0] == "step"]
    restarts = [h for h in history if h[0] == "restart"]
    losses = [float(m["loss"]) for _, _, m in steps]
    print(f"arch={cfg.name} update={args.update} steps={len(steps)} "
          f"restarts={len(restarts)} "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({time.time()-t0:.1f}s)")
    assert losses[-1] < losses[0], "loss did not decrease"
    return losses


if __name__ == "__main__":
    main()
