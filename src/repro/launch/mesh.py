"""Production meshes.  Functions, not module constants — importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis is
    the slow DCN boundary — the async-local replica axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int | None = None):
    """Tiny mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    model = model or 1
    return jax.make_mesh((n // model, model), ("data", "model"))
