"""Sparse training data in ELL (padded) format — the TPU-native CSR analogue.

The paper converts CSR to a zero-padded dense-width format for the col-major
GPU access path (Section 5.2.1: "we map sparse data into a dense padded
format that stores all the examples at the same width — equal to the maximum
number of non-zero features").  On TPU the same trade is forced globally:
variable-length rows are hostile to fixed-shape tiles, so we adopt ELL:

    values  : [N, K]  float   (zero padded)
    indices : [N, K]  int32   (index 0 padded; padded values are 0 so the
                               contribution vanishes)

with K = max nnz/row (optionally a high percentile with overflow rows split).
The GLM margin is a gather-dot; the gradient is a scatter-add, both expressed
with jnp.take / segment_sum so they lower to XLA gather/scatter on TPU.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class ELLMatrix(NamedTuple):
    """Padded sparse matrix (ELLPACK layout)."""

    values: Array   # [N, K] float
    indices: Array  # [N, K] int32
    d: int          # number of features (model dimension)

    @property
    def shape(self) -> tuple[int, int]:
        return (self.values.shape[0], self.d)

    @property
    def max_nnz(self) -> int:
        return self.values.shape[1]


class CSRMatrix(NamedTuple):
    """Host-side CSR triple — the ingestion-facing sparse layout.

    This is the representation parsers produce (variable-length rows,
    no padding); ``to_ell`` converts to the TPU-native padded layout.
    All arrays are numpy: CSR never reaches a kernel directly.
    """

    indptr: np.ndarray   # [N+1] int64 row offsets
    indices: np.ndarray  # [nnz] int32 column ids
    values: np.ndarray   # [nnz] float32
    d: int               # number of features (model dimension)

    @property
    def shape(self) -> tuple[int, int]:
        return (len(self.indptr) - 1, self.d)

    @property
    def n(self) -> int:
        return len(self.indptr) - 1

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    @property
    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr)

    @property
    def avg_nnz(self) -> float:
        return float(self.nnz / max(self.n, 1))

    def row(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        lo, hi = int(self.indptr[i]), int(self.indptr[i + 1])
        return self.indices[lo:hi], self.values[lo:hi]

    def select(self, rows: np.ndarray) -> "CSRMatrix":
        """Row subset (host-side, vectorized — used by train/test splits)."""
        rows = np.asarray(rows, dtype=np.int64)
        counts = self.row_nnz[rows]
        indptr = np.zeros(len(rows) + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        # absolute source index = row start + offset within the row
        within = np.arange(int(indptr[-1]), dtype=np.int64) \
            - np.repeat(indptr[:-1], counts)
        take = np.repeat(self.indptr[rows], counts) + within
        return CSRMatrix(indptr, self.indices[take], self.values[take], self.d)

    def to_ell(self, pad_to: int | None = None) -> ELLMatrix:
        """Zero-padded ELL conversion, vectorized (the paper's padded-width
        format, §5.2.1: every row stored at the same width).  ``pad_to``
        defaults to the maximum row width so no entry is dropped; an
        explicit narrower ``pad_to`` truncates overflow rows."""
        N = self.n
        K = int(self.row_nnz.max()) if (pad_to is None and N) else (pad_to or 1)
        K = max(K, 1)
        values = np.zeros((N, K), dtype=np.float32)
        indices = np.zeros((N, K), dtype=np.int32)
        if self.nnz:
            row_of = np.repeat(np.arange(N, dtype=np.int64), self.row_nnz)
            pos = np.arange(self.nnz, dtype=np.int64) \
                - np.repeat(self.indptr[:-1], self.row_nnz)
            keep = pos < K
            values[row_of[keep], pos[keep]] = self.values[keep]
            indices[row_of[keep], pos[keep]] = self.indices[keep]
        return ELLMatrix(jnp.asarray(values), jnp.asarray(indices), self.d)

    def to_dense(self) -> np.ndarray:
        """Densify host-side (dense datasets and tests — O(N*d))."""
        out = np.zeros((self.n, self.d), dtype=np.float32)
        rows = np.repeat(np.arange(self.n), self.row_nnz)
        np.add.at(out, (rows, self.indices), self.values)
        return out


def from_csr_parts(
    rows_idx: list[np.ndarray], rows_val: list[np.ndarray], d: int
) -> CSRMatrix:
    """Assemble a ``CSRMatrix`` from per-row (indices, values) pairs."""
    indptr = np.zeros(len(rows_idx) + 1, dtype=np.int64)
    np.cumsum([len(r) for r in rows_idx], out=indptr[1:])
    indices = (np.concatenate(rows_idx).astype(np.int32)
               if rows_idx else np.zeros(0, dtype=np.int32))
    values = (np.concatenate(rows_val).astype(np.float32)
              if rows_val else np.zeros(0, dtype=np.float32))
    return CSRMatrix(indptr, indices, values, d)


def from_dense(X: np.ndarray, pad_to: int | None = None) -> ELLMatrix:
    """Build an ELLMatrix from a dense [N, d] array (host-side, numpy)."""
    N, d = X.shape
    nnz_per_row = (X != 0).sum(axis=1)
    K = int(nnz_per_row.max()) if pad_to is None else pad_to
    K = max(K, 1)
    values = np.zeros((N, K), dtype=X.dtype)
    indices = np.zeros((N, K), dtype=np.int32)
    for i in range(N):
        (nz,) = np.nonzero(X[i])
        nz = nz[:K]
        values[i, : len(nz)] = X[i, nz]
        indices[i, : len(nz)] = nz
    return ELLMatrix(jnp.asarray(values), jnp.asarray(indices), d)


def from_rows(
    rows_idx: list[np.ndarray], rows_val: list[np.ndarray], d: int,
    pad_to: int | None = None,
) -> ELLMatrix:
    """Build from per-row (indices, values) pairs — CSR-style input."""
    N = len(rows_idx)
    K = pad_to if pad_to is not None else max((len(r) for r in rows_idx), default=1)
    K = max(K, 1)
    values = np.zeros((N, K), dtype=np.float32)
    indices = np.zeros((N, K), dtype=np.int32)
    for i, (idx, val) in enumerate(zip(rows_idx, rows_val)):
        k = min(len(idx), K)
        values[i, :k] = val[:k]
        indices[i, :k] = idx[:k]
    return ELLMatrix(jnp.asarray(values), jnp.asarray(indices), d)


def to_dense(m: ELLMatrix) -> Array:
    """Densify (testing only — O(N*d))."""
    N, K = m.values.shape
    out = jnp.zeros((N, m.d), dtype=m.values.dtype)
    rows = jnp.repeat(jnp.arange(N), K)
    return out.at[rows, m.indices.reshape(-1)].add(m.values.reshape(-1))


# ---------------------------------------------------------------------------
# Sparse GLM margin / gradient
# ---------------------------------------------------------------------------


def margins(m: ELLMatrix, w: Array) -> Array:
    """x_i . w for every row — gather model features then row-sum.

    The gather is the TPU analogue of the paper's coalesced model access: the
    [N, K] index block is a single gather op, contiguous in the example axis.
    """
    wg = jnp.take(w, m.indices, axis=0)          # [N, K]
    return jnp.sum(m.values * wg, axis=1)        # [N]


def grad(task: str, m: ELLMatrix, y: Array, w: Array) -> Array:
    """Sum GLM gradient: scatter-add of pull_i * values_i into w-space."""
    from repro.core import glm

    mar = y * margins(m, w)
    pull = glm.PULLS[task](mar, y)               # [N]
    contrib = m.values * pull[:, None]           # [N, K]
    flat_idx = m.indices.reshape(-1)
    flat_val = contrib.reshape(-1)
    return jax.ops.segment_sum(flat_val, flat_idx, num_segments=m.d)


def loss(task: str, m: ELLMatrix, y: Array, w: Array) -> Array:
    from repro.core import glm

    mar = y * margins(m, w)
    if task == "lr":
        return jnp.sum(jnp.maximum(-mar, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(mar))))
    return jnp.sum(jnp.maximum(0.0, 1.0 - mar))


def incremental_epoch(task: str, w: Array, m: ELLMatrix, y: Array, step: float) -> Array:
    """Per-example sparse SGD epoch (sequential oracle), scanned.

    Each step touches only the K nonzero features of the example — the
    sparse-update property that makes Hogwild converge (Niu et al. 2011).
    """
    from repro.core import glm

    pull_fn = glm.PULLS[task]

    def body(w, xy):
        vals, idx, y_i = xy
        wg = jnp.take(w, idx, axis=0)
        margin = y_i * jnp.dot(vals, wg)
        pull = pull_fn(margin, y_i)
        return w.at[idx].add(-step * pull * vals), None

    w_out, _ = jax.lax.scan(body, w, (m.values, m.indices, y))
    return w_out


def minibatch_epoch(
    task: str, w: Array, m: ELLMatrix, y: Array, step: float, batch: int
) -> Array:
    """Mini-batch sparse SGD epoch (per-replica rule of the async engine)."""
    n = m.values.shape[0]
    assert n % batch == 0, (n, batch)
    K = m.values.shape[1]
    vb = m.values.reshape(n // batch, batch, K)
    ib = m.indices.reshape(n // batch, batch, K)
    yb = y.reshape(n // batch, batch)

    def body(w, xiy):
        vals, idx, yk = xiy
        g = grad(task, ELLMatrix(vals, idx, m.d), yk, w)
        return w - (step / batch) * g, None

    w_out, _ = jax.lax.scan(body, w, (vb, ib, yb))
    return w_out
