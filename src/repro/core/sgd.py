"""Parallel SGD engine — the paper's exploratory axes as first-class config.

Exploratory axes (paper Fig. 1) and how they appear here:

* **Model-update strategy** — ``SyncSGD`` (Algorithm 2: one transactional
  update per pass; statistical efficiency identical to sequential) vs
  ``AsyncLocalSGD`` (Hogwild-family: R model replicas doing independent
  incremental/mini-batch updates over their partitions, merged periodically —
  the DimmWitted per-NUMA-node scheme of paper §5.1, which is the faithful
  TPU-expressible analogue of lock-free Hogwild; see DESIGN.md §2).

* **Model replication** (paper Table 2: kernel / block / thread) — the replica
  count R.  R=1 ≙ ``kernel`` (one shared model), R=#devices ≙ ``block``,
  R≫#devices ≙ ``thread``.  More replicas ⇒ better hardware efficiency
  (fewer/cheaper merges) and worse statistical efficiency — the paper's
  central trade-off, reproduced measurably.

* **Data access path** (row-rr / row-ch) — the example→replica assignment:
  ``round_robin`` interleaves examples, ``chunk`` gives contiguous ranges.
  (col-major is a *layout* choice inside the compute kernel — see
  kernels/glm_grad — not a partitioning choice.)

* **Data replication** (no-rep / rep-k) — each replica receives its partition
  plus ``rep_k`` halo examples from the neighbouring partition (paper
  §5.2.3), trading one extra pass-fraction of hardware efficiency for
  statistical efficiency.

The single-host study engine emulates R replicas with ``vmap`` (replica axis
is a real array axis), so statistical efficiency measurements are exact and
reproducible; the distributed trainer (train/trainer.py) runs the same
schedule over mesh axes with one replica per device/pod.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import glm, sparse
from repro.obs import trace

Array = jax.Array

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

AccessPath = Literal["round_robin", "chunk"]
MergeScheme = Literal["mean", "weighted"]


@dataclasses.dataclass(frozen=True)
class SyncSGD:
    """Synchronous (transactional) updates — paper's synchronous axis.

    ``batch`` = B in Algorithm 1.  B=N gives batch gradient descent (the
    TF/BIDMach/ViennaCL configuration of the paper's experiments); smaller B
    gives mini-batch synchronous SGD with an update barrier per batch.

    ``kernel_backend`` routes the gradient/epoch computation through the
    kernel dispatch registry (``pallas-tpu`` / ``pallas-interpret`` /
    ``reference`` — see DESIGN.md §3) instead of the inline XLA
    expressions; None keeps the pure-XLA path.  Dense data supports any
    batch size (full-batch → glm_grad, mini-batch → glm_sgd); sparse
    data supports full-batch only (glm_sparse).
    """

    batch: int | None = None  # None -> full batch (B = N)
    kernel_backend: str | None = None

    @property
    def name(self) -> str:
        base = "sync" if self.batch is None else f"sync-b{self.batch}"
        if self.kernel_backend:
            base += f"[{self.kernel_backend}]"
        return base


@dataclasses.dataclass(frozen=True)
class AsyncLocalSGD:
    """Asynchronous replica-merge updates — paper's asynchronous axis.

    replicas      R model replicas (model-replication granularity).
    local_batch   per-replica update granularity (1 = incremental Hogwild).
    merge_every   merge period in *epochs*; <1 merges multiple times per
                  epoch (e.g. 0.25 ⇒ 4 merges/epoch).  Staleness knob.
    access        example→replica assignment (row-rr vs row-ch).
    rep_k         halo data replication (paper §5.2.3).

    ``kernel_backend`` mirrors ``SyncSGD.kernel_backend``: replica epochs
    route through the kernel dispatch registry (dense → glm_sgd's fused
    epoch vmapped over the replica axis; sparse → glm_sparse, which is a
    sum-gradient kernel and therefore needs full-partition local updates,
    ``local_batch`` == partition size).  None keeps the pure-XLA path.
    """

    replicas: int = 8
    local_batch: int = 1
    merge_every: float = 1.0
    access: AccessPath = "chunk"
    rep_k: int = 0
    merge: MergeScheme = "mean"
    kernel_backend: str | None = None

    @property
    def name(self) -> str:
        base = (
            f"async-r{self.replicas}-b{self.local_batch}"
            f"-m{self.merge_every}-{self.access[:5]}-rep{self.rep_k}"
        )
        if self.kernel_backend:
            base += f"[{self.kernel_backend}]"
        return base


# ---------------------------------------------------------------------------
# Data partitioning (access path + rep-k halos)
# ---------------------------------------------------------------------------


def partition_indices(
    n: int, replicas: int, access: AccessPath = "chunk", rep_k: int = 0
) -> np.ndarray:
    """Example→replica assignment matrix ``[replicas, per + rep_k]``.

    ``chunk``       replica r gets the contiguous range [r*per, (r+1)*per).
    ``round_robin`` replica r gets examples r, r+R, r+2R, ...
    ``rep_k``       each replica additionally gets the first ``rep_k``
                    examples of the *next* replica's partition (cyclic halo),
                    preserving sequential access — paper §5.2.3.
    """
    per = n // replicas
    n_eff = per * replicas
    base = np.arange(n_eff)
    if access == "chunk":
        parts = base.reshape(replicas, per)
    elif access == "round_robin":
        parts = base.reshape(per, replicas).T
    else:
        raise ValueError(access)
    if rep_k > 0:
        # halo = the first rep_k examples of the *following* partitions in
        # cyclic order (wraps across several partitions when rep_k > per)
        halos = []
        for r in range(replicas):
            stream = np.concatenate(
                [parts[(r + s) % replicas] for s in range(1, replicas + 1)])
            halos.append(stream[:rep_k])
        parts = np.concatenate([parts, np.stack(halos, axis=0)], axis=1)
    return parts.astype(np.int32)


# ---------------------------------------------------------------------------
# Epoch execution
# ---------------------------------------------------------------------------


def _dense_replica_epoch(task, W, Xp, yp, step, local_batch):
    """One local epoch on every replica (vmap over the replica axis)."""

    def one(w, X, y):
        if local_batch == 1:
            return glm.incremental_epoch(task, w, X, y, step)
        return glm.minibatch_epoch(task, w, X, y, step, local_batch)

    return jax.vmap(one)(W, Xp, yp)


def _sparse_replica_epoch(task, W, vals, idx, d, yp, step, local_batch):
    def one(w, v, i, y):
        m = sparse.ELLMatrix(v, i, d)
        if local_batch == 1:
            return sparse.incremental_epoch(task, w, m, y, step)
        return sparse.minibatch_epoch(task, w, m, y, step, local_batch)

    return jax.vmap(one)(W, vals, idx, yp)


def merge_replicas(W: Array, scheme: MergeScheme = "mean") -> Array:
    """Replica merge: average and redistribute (paper §5.1 merge thread)."""
    if scheme == "mean":
        mean = jnp.mean(W, axis=0)
        return jnp.broadcast_to(mean, W.shape)
    raise ValueError(scheme)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunResult:
    """History of one SGD run (the three performance axes derive from it)."""

    losses: np.ndarray          # [epochs+1] loss after each epoch (incl. init)
    epoch_times: np.ndarray     # [epochs]   wall seconds per epoch
    strategy: str
    task: str

    def epochs_to(self, target: float) -> int | None:
        """Statistical efficiency: #epochs to reach loss <= target."""
        hit = np.nonzero(self.losses <= target)[0]
        return int(hit[0]) if len(hit) else None

    def time_to(self, target: float) -> float | None:
        """Time to convergence: sum of epoch times until target reached."""
        e = self.epochs_to(target)
        if e is None:
            return None
        return float(np.sum(self.epoch_times[:e]))

    @property
    def time_per_epoch(self) -> float:
        """Hardware efficiency: mean seconds per epoch."""
        return float(np.mean(self.epoch_times))


def make_epoch_fn(
    problem: glm.GLMProblem | tuple,
    strategy,
    *,
    sparse_data: bool = False,
    step_param: bool = False,
):
    """Build a jitted ``(w_state) -> w_state`` epoch function + initial state.

    Returns ``(init_state, epoch_fn, loss_fn, merges_per_epoch)``.  For
    SyncSGD the state is ``w [d]``; for AsyncLocalSGD it is ``W [R, d]``.

    With ``step_param=True`` the epoch function takes ``(state, step)``
    with the step size as a traced scalar instead of baking the problem's
    step in — the study runner vmaps it over a stacked step axis to run a
    whole §6.1 step-size grid in one program.  Kernel-backend epochs bake
    the step statically (it is a kernel compile-time constant) and refuse
    ``step_param``.
    """
    if sparse_data:
        task, m, y, step0 = problem
        n, d = m.shape
    else:
        task, X, y, step0 = problem.task, problem.X, problem.y, problem.step
        n, d = X.shape
        m = None

    def _finalize(epoch_of_step):
        """Bind the step statically, or expose it as a traced argument."""
        if step_param:
            return jax.jit(epoch_of_step)
        return jax.jit(lambda state: epoch_of_step(state, step0))

    if isinstance(strategy, SyncSGD):
        batch = strategy.batch or n
        backend = strategy.kernel_backend
        if backend is not None and step_param:
            raise ValueError(
                "step_param needs kernel_backend=None (kernel epochs bake "
                "the step size as a compile-time constant)")

        if sparse_data:
            if backend is not None:
                # full-batch -> glm_sparse (sum gradient); mini-batch ->
                # glm_sgd_sparse (fused epoch, model resident in VMEM)
                from repro.kernels.glm_sgd_sparse import (
                    ell_sgd_epoch as _kepoch_sp,
                )
                from repro.kernels.glm_sparse import ell_glm_grad as _kgrad_sp

                @jax.jit
                def epoch(w):
                    if batch >= n:
                        g = _kgrad_sp(task, w, m.values, m.indices, y,
                                      backend=backend)
                        return w - step0 * g
                    return _kepoch_sp(task, w, m.values, m.indices, y,
                                      step=step0, micro_batch=batch,
                                      backend=backend)

            else:

                def epoch_s(w, step):
                    if batch >= n:
                        g = sparse.grad(task, m, y, w)
                        return w - (step / n) * g * n  # alpha on sum grad
                    return sparse.minibatch_epoch(task, w, m, y, step, batch)

                epoch = _finalize(epoch_s)

            @jax.jit
            def loss_fn(w):
                return sparse.loss(task, m, y, w)

        else:
            if backend is not None:
                # route through the kernel dispatch registry: full-batch ->
                # glm_grad (fused sum gradient), mini-batch -> glm_sgd
                # (fused epoch, model resident in VMEM on TPU)
                from repro.kernels.glm_grad import glm_grad as _kgrad
                from repro.kernels.glm_sgd import glm_sgd_epoch as _kepoch

                @jax.jit
                def epoch(w):
                    if batch >= n:
                        g = _kgrad(task, w, X, y, backend=backend)
                        return w - step0 * g
                    return _kepoch(task, w, X, y, step=step0,
                                   micro_batch=batch, backend=backend)

            else:

                def epoch_s(w, step):
                    if batch >= n:
                        g = glm.grad_fused(task, w, X, y)
                        return w - step * g
                    return glm.minibatch_epoch(task, w, X, y, step, batch)

                epoch = _finalize(epoch_s)

            @jax.jit
            def loss_fn(w):
                return glm.LOSSES[task](w, X, y)

        init = jnp.zeros((d,), dtype=jnp.float32)
        return init, epoch, loss_fn, 0

    assert isinstance(strategy, AsyncLocalSGD)
    R = strategy.replicas
    backend = strategy.kernel_backend
    if backend is not None and step_param:
        raise ValueError(
            "step_param needs kernel_backend=None (kernel epochs bake "
            "the step size as a compile-time constant)")
    parts = partition_indices(n, R, strategy.access, strategy.rep_k)
    per = parts.shape[1]
    merges = max(1, int(round(1.0 / strategy.merge_every))) if strategy.merge_every <= 1 else 1
    # merge_every > 1 handled by the driver (merge every int(merge_every) epochs)

    if sparse_data:
        vals_p = jnp.take(m.values, parts, axis=0)   # [R, per, K]
        idx_p = jnp.take(m.indices, parts, axis=0)
        y_p = jnp.take(y, parts, axis=0)

        if backend is not None:
            if strategy.local_batch == per:
                # full-partition update: glm_sparse sum gradient
                from repro.kernels.glm_sparse import ell_glm_grad as _kgrad_sp

                def _replica_epoch(W, step):
                    def one(w, v, i, yr):
                        g = _kgrad_sp(task, w, v, i, yr, backend=backend)
                        return w - (step / per) * g

                    return jax.vmap(one)(W, vals_p, idx_p, y_p)

            elif per % strategy.local_batch == 0:
                # mini-batch local updates: fused sparse-SGD epoch kernel
                from repro.kernels.glm_sgd_sparse import (
                    ell_sgd_epoch as _kepoch_sp,
                )

                def _replica_epoch(W, step):
                    def one(w, v, i, yr):
                        return _kepoch_sp(task, w, v, i, yr, step=step,
                                          micro_batch=strategy.local_batch,
                                          backend=backend)

                    return jax.vmap(one)(W, vals_p, idx_p, y_p)

            else:
                raise ValueError(
                    f"kernel_backend epochs need local_batch to divide the "
                    f"partition size {per} (= n//replicas + rep_k), got "
                    f"{strategy.local_batch}")

        else:

            def _replica_epoch(W, step):
                return _sparse_replica_epoch(
                    task, W, vals_p, idx_p, d, y_p, step, strategy.local_batch)

        def epoch_s(W, step):
            for _ in range(merges):
                W = _replica_epoch(W, step)
                W = merge_replicas(W, strategy.merge)
            return W

        epoch = _finalize(epoch_s)

        @jax.jit
        def loss_fn(W):
            return sparse.loss(task, m, y, W[0])

    else:
        Xp = jnp.take(X, parts, axis=0)              # [R, per, d]
        y_p = jnp.take(y, parts, axis=0)

        if backend is not None:
            if per % strategy.local_batch != 0:
                raise ValueError(
                    f"kernel_backend epochs need local_batch to divide the "
                    f"partition size {per} (= n//replicas + rep_k), got "
                    f"{strategy.local_batch}")
            from repro.kernels.glm_sgd import glm_sgd_epoch as _kepoch

            def _replica_epoch(W, step):
                def one(w, Xr, yr):
                    return _kepoch(task, w, Xr, yr, step=step,
                                   micro_batch=strategy.local_batch,
                                   backend=backend)

                return jax.vmap(one)(W, Xp, y_p)

        else:

            def _replica_epoch(W, step):
                return _dense_replica_epoch(
                    task, W, Xp, y_p, step, strategy.local_batch)

        def epoch_s(W, step):
            for _ in range(merges):
                W = _replica_epoch(W, step)
                W = merge_replicas(W, strategy.merge)
            return W

        epoch = _finalize(epoch_s)

        @jax.jit
        def loss_fn(W):
            return glm.LOSSES[task](W[0], X, y)

    init = jnp.zeros((R, d), dtype=jnp.float32)
    return init, epoch, loss_fn, merges


def run(
    problem,
    strategy,
    epochs: int,
    *,
    sparse_data: bool = False,
    record_time: bool = True,
) -> RunResult:
    """Run SGD for ``epochs`` passes, recording loss + wall time per pass."""
    import time

    init, epoch_fn, loss_fn, merges = make_epoch_fn(
        problem, strategy, sparse_data=sparse_data)
    task = problem[0] if sparse_data else problem.task

    state = init
    losses = [float(loss_fn(state))]
    times = []
    # warmup compile outside the timed region
    with trace.span("engine.compile", strategy=strategy.name, task=task):
        state_c = epoch_fn(state)
        jax.block_until_ready(state_c)
    state = state_c
    losses.append(float(loss_fn(state)))
    times.append(float("nan"))  # epoch 1 time includes compile; exclude
    for e in range(epochs - 1):
        # host-level epoch span: for async strategies the epoch body fuses
        # `merges` replica-merge rounds (merge_replicas runs inside jit)
        with trace.span("engine.epoch", epoch=e + 1, strategy=strategy.name,
                        merges=merges):
            t0 = time.perf_counter()
            state = epoch_fn(state)
            jax.block_until_ready(state)
            times.append(time.perf_counter() - t0)
        losses.append(float(loss_fn(state)))
    # replace the compile-epoch time with the median of the rest
    if len(times) > 1:
        times[0] = float(np.nanmedian(times[1:]))
    else:
        times[0] = 0.0
    return RunResult(
        losses=np.asarray(losses),
        epoch_times=np.asarray(times),
        strategy=strategy.name,
        task=task,
    )
