"""Convergence methodology from the paper's experimental setup (§6.1).

* optimal loss = lowest loss seen by any configuration within a budget;
* convergence thresholds at 10%, 5%, 2%, 1% above the optimum;
* step size chosen by gridding powers of 10 and picking the fastest
  time-to-convergence (paper: "griding its range in powers of 10").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

import numpy as np

from repro.core import sgd as sgd_mod


DEFAULT_TOLERANCES = (0.10, 0.05, 0.02, 0.01)


def thresholds(optimal_loss: float, tolerances: Sequence[float] = DEFAULT_TOLERANCES):
    """Loss values 'within t of the optimum' for each tolerance t."""
    return {t: optimal_loss * (1.0 + t) if optimal_loss >= 0 else optimal_loss * (1.0 - t)
            for t in tolerances}


def grid_step_sizes(lo_exp: int = -6, hi_exp: int = 2) -> list[float]:
    """{1e-6, 1e-5, ..., 1e2} — the paper's step-size grid."""
    return [10.0 ** e for e in range(lo_exp, hi_exp + 1)]


def rank_key(result, target: float, *, by: str = "time") -> tuple:
    """Paper §6.1 selection order as a sort key (lower is better).

    Converged runs rank first — by measured time-to-target (``by="time"``)
    or by epochs-to-target (``by="epochs"``, deterministic under a fixed
    seed: no wall-clock in the key); non-converged runs rank by final
    loss; diverged (non-finite) runs rank last.  Works on any result with
    ``losses`` / ``time_to`` / ``epochs_to`` (``sgd.RunResult`` and the
    study runner's ``TrialResult``).
    """
    last = float(result.losses[-1])
    if not np.isfinite(last):
        return (2, math.inf)
    hit = result.time_to(target) if by == "time" else result.epochs_to(target)
    if hit is None:
        return (1, last)
    return (0, float(hit))


@dataclasses.dataclass
class GridSearchResult:
    best_step: float
    best_result: "sgd_mod.RunResult"
    all_results: dict  # step -> RunResult


def grid_search_step(
    make_problem,
    strategy,
    epochs: int,
    target: float,
    *,
    steps: Iterable[float] | None = None,
    sparse_data: bool = False,
) -> GridSearchResult:
    """Paper §6.1 step-size selection: fastest time to ``target`` wins.

    ``make_problem(step) -> problem`` lets the caller embed the step size.
    Falls back to lowest final loss when no step reaches the target.

    This is the low-level, problem-object API.  Sweeps expressed as
    ``study.spec.TrialSpec``s should use ``study.tuner.tune_step`` — same
    selection rule, but with trial caching and vmap-stacked step grids.
    """
    steps = list(steps) if steps is not None else grid_step_sizes()
    results: dict[float, sgd_mod.RunResult] = {}
    best_step, best_key = None, None
    for s in steps:
        res = sgd_mod.run(make_problem(s), strategy, epochs, sparse_data=sparse_data)
        results[s] = res
        if not np.isfinite(res.losses[-1]):
            continue  # diverged
        key = rank_key(res, target)
        if best_key is None or key < best_key:
            best_key, best_step = key, s
    if best_step is None:  # everything diverged: pick smallest step
        best_step = min(steps)
    return GridSearchResult(best_step, results[best_step], results)


def optimal_loss(results: Iterable["sgd_mod.RunResult"]) -> float:
    """Paper methodology: run all configurations, lowest loss observed wins."""
    best = math.inf
    for r in results:
        finite = r.losses[np.isfinite(r.losses)]
        if len(finite):
            best = min(best, float(finite.min()))
    return best
