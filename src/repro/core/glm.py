"""Generalized linear models (LR / SVM) — losses, gradients, execution paths.

This is the computational heart of the paper (Ma, Rusu, Torres 2018):
binary classification with logistic regression

    f_LR(w)  = log(1 + exp(-y * x.w))
    dLR/dw_j = x_j * (-y * sigma(-y * x.w))        [sigma = logistic]

and linear SVM (hinge loss)

    f_SVM(w) = max(0, 1 - y * x.w)
    dSVM/dw_j = -y * x_j   if  y * x.w < 1  else 0

Three execution paths are provided, mirroring the paper's implementations:

``grad_primitive_composition``
    The ViennaCL / TensorFlow / BIDMach strategy (paper Section 4): a chain of
    *blocking* linear-algebra primitives with full materialization between
    them.  We reproduce the materialization boundary with
    ``lax.optimization_barrier`` so XLA cannot fuse across primitives — this
    is the faithful baseline whose hardware efficiency the paper's fused
    kernels beat.

``grad_fused``
    A single fused expression (what the paper's hand-written kernels achieve
    by fusing the gradient pipeline); XLA fuses it into one or two kernels.
    Mathematically identical to the composition path.

``kernels/glm_grad`` (see that package)
    The Pallas TPU kernel: tiled over examples, model broadcast in VMEM,
    MXU matmuls for x.w and X^T r.

All paths operate on a *batch*: ``X: [B, d]``, ``y: [B]`` (labels in
{-1, +1}), ``w: [d]`` and return the *sum* gradient over the batch (the
paper's Algorithm 2 accumulates sums; callers divide by B if they want the
mean).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def lr_loss(w: Array, X: Array, y: Array) -> Array:
    """Sum logistic loss over the batch.  log1p(exp(-m)) with stable form."""
    margins = y * (X @ w)
    # log(1 + e^-m) = max(-m, 0) + log1p(exp(-|m|))  (numerically stable)
    return jnp.sum(jnp.maximum(-margins, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(margins))))


def svm_loss(w: Array, X: Array, y: Array) -> Array:
    """Sum hinge loss over the batch."""
    margins = y * (X @ w)
    return jnp.sum(jnp.maximum(0.0, 1.0 - margins))


LOSSES: dict[str, Callable[[Array, Array, Array], Array]] = {
    "lr": lr_loss,
    "svm": svm_loss,
}

# ---------------------------------------------------------------------------
# Per-example "pull" (the scalar that multiplies x_i in the gradient)
# ---------------------------------------------------------------------------
# grad = X^T @ pull(margins) with margins = y * (X @ w):
#   LR : pull = -y * sigmoid(-margin)
#   SVM: pull = -y * (margin < 1)


def lr_pull(margins: Array, y: Array) -> Array:
    return -y * jax.nn.sigmoid(-margins)


def svm_pull(margins: Array, y: Array) -> Array:
    return -y * (margins < 1.0).astype(margins.dtype)


PULLS: dict[str, Callable[[Array, Array], Array]] = {
    "lr": lr_pull,
    "svm": svm_pull,
}

# ---------------------------------------------------------------------------
# Inference links (margin -> served score)
# ---------------------------------------------------------------------------
# Training consumes margins through the pull functions above; *serving*
# consumes them through a link: LR responses are calibrated probabilities
# sigma(x.w), SVM responses are the raw decision value x.w (sign = class,
# magnitude = distance to the separating hyperplane).  The scoring kernel
# family (kernels/glm_score) fuses the link into the margin launch, and
# its oracle is defined against these functions.


def lr_link(margins: Array) -> Array:
    return jax.nn.sigmoid(margins)


def svm_link(margins: Array) -> Array:
    return margins


LINKS: dict[str, Callable[[Array], Array]] = {
    "lr": lr_link,
    "svm": svm_link,
}


# ---------------------------------------------------------------------------
# Execution path 1: primitive composition (ViennaCL / TF / BIDMach analogue)
# ---------------------------------------------------------------------------


def _barrier(x: Array) -> Array:
    """Materialization boundary — the analogue of a blocking ViennaCL call."""
    return lax.optimization_barrier(x)


def grad_primitive_composition(task: str, w: Array, X: Array, y: Array) -> Array:
    """Paper Section 4 function sequence, one barrier per primitive.

    For LR the sequence is literally the one listed in the paper:
        a = matrix-vector-product(data, model)
        a = vector-vector-element-product(label, a)
        a = vector-element-exponent(-a)              (folded sign)
        b = vector-element-sum(1, a)
        a = vector-vector-element-division(a, b)
        a = vector-vector-element-product(a, -label)
        g = matrix-vector-product(transpose(data), a)
    """
    if task == "lr":
        a = _barrier(X @ w)                         # matrix-vector product
        a = _barrier(y * a)                         # element product
        a = _barrier(jnp.exp(-a))                   # element exponent
        b = _barrier(1.0 + a)                       # element sum
        a = _barrier(a / b)                         # element division
        a = _barrier(a * (-y))                      # element product w/ -label
        return X.T @ a                              # matrix-vector product (X^T)
    elif task == "svm":
        a = _barrier(X @ w)
        a = _barrier(y * a)
        mask = _barrier((a < 1.0).astype(X.dtype))
        a = _barrier(mask * (-y))
        return X.T @ a
    raise ValueError(f"unknown task {task!r}")


# ---------------------------------------------------------------------------
# Execution path 2: fused expression (XLA fuses the whole pipeline)
# ---------------------------------------------------------------------------


def grad_fused(task: str, w: Array, X: Array, y: Array) -> Array:
    margins = y * (X @ w)
    pull = PULLS[task](margins, y)
    return X.T @ pull


def loss_and_grad(task: str, w: Array, X: Array, y: Array) -> tuple[Array, Array]:
    """Fused loss + gradient in one pass (shares the X @ w matvec)."""
    margins = y * (X @ w)
    if task == "lr":
        loss = jnp.sum(jnp.maximum(-margins, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(margins))))
    else:
        loss = jnp.sum(jnp.maximum(0.0, 1.0 - margins))
    pull = PULLS[task](margins, y)
    return loss, X.T @ pull


# ---------------------------------------------------------------------------
# Incremental (per-example) SGD epoch — the sequential oracle
# ---------------------------------------------------------------------------


def incremental_epoch(task: str, w: Array, X: Array, y: Array, step: float) -> Array:
    """Paper Algorithm 3: for each example, grad estimate then model update.

    This is the *sequential* semantics that Hogwild approximates; it is the
    statistical-efficiency gold standard (no update conflicts).  Implemented
    as lax.scan over examples so it jits to O(1) HLO.
    """
    pull_fn = PULLS[task]

    def body(w, xy):
        x_i, y_i = xy
        margin = y_i * jnp.dot(x_i, w)
        pull = pull_fn(margin, y_i)
        return w - step * pull * x_i, None

    w_out, _ = lax.scan(body, w, (X, y))
    return w_out


def minibatch_epoch(
    task: str, w: Array, X: Array, y: Array, step: float, batch: int
) -> Array:
    """Mini-batch SGD epoch: model updated every ``batch`` examples.

    ``N`` must be divisible by ``batch``; callers pad/truncate.  This is the
    middle ground between the paper's batch (B=N) and incremental (B=1)
    variants, and is the per-replica update rule of the async-local engine.
    """
    n = X.shape[0]
    assert n % batch == 0, (n, batch)
    Xb = X.reshape(n // batch, batch, X.shape[1])
    yb = y.reshape(n // batch, batch)

    def body(w, xy):
        Xk, yk = xy
        g = grad_fused(task, w, Xk, yk)
        return w - (step / batch) * g, None

    w_out, _ = lax.scan(body, w, (Xb, yb))
    return w_out


# ---------------------------------------------------------------------------
# Model / problem container
# ---------------------------------------------------------------------------


class GLMProblem(NamedTuple):
    """A training problem instance: task + data + hyper-parameters."""

    task: str            # "lr" | "svm"
    X: Array             # [N, d]  (dense)  — sparse problems use core.sparse
    y: Array             # [N]     in {-1, +1}
    step: float          # SGD step size alpha


def full_loss(problem: GLMProblem, w: Array) -> Array:
    return LOSSES[problem.task](w, problem.X, problem.y)


@functools.partial(jax.jit, static_argnums=(0,))
def batch_gd_epoch(task: str, w: Array, X: Array, y: Array, step: Array) -> Array:
    """Paper Algorithm 2 (batch SGD = full gradient, one update per epoch)."""
    g = grad_fused(task, w, X, y)
    return w - step * g
