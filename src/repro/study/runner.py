"""Trial execution: deterministic seeds, timing, caching, vmap stacking.

The runner turns ``TrialSpec``s into ``TrialResult``s:

* **trial cache** — results are keyed by the spec's content hash and
  persisted as one JSON file per trial, so an interrupted sweep resumes
  where it stopped instead of recomputing, and a repeated sweep is a
  pure cache read (byte-identical results, which is what makes
  ``BENCH_study.json`` reproducible across runs);
* **vmap stacking** — trials that differ only in step size (the §6.1
  grid) share one compiled program: the epoch function is built with
  ``step_param=True`` and vmapped over a stacked ``[S, ...]`` state +
  ``[S]`` step vector.  Wall time is measured for the stack and
  amortized per trial (flagged ``stacked`` in the result meta);
* **dataset memoization** — datasets (synthetic generations and real
  ingests alike) are materialized once per ``DatasetSpec`` per runner;
* **executor dispatch** — with an ``executor`` attached (see
  ``repro.sweep``), cache-miss trials spanning at least
  ``dispatch_min_groups`` stack groups are not executed in-process:
  they are handed to the executor, which must leave their payloads in
  the canonical cache (N workers, merged), and the runner then reads
  the results back from the cache.  A single stack group cannot
  parallelize, so it runs in-process even with an executor attached.
  Cache hits, store recording, and result ordering are identical
  either way, which is what keeps ``BENCH_study.json`` a pure function
  of the trial cache.

Cache keys come from ``TrialSpec.key``; for ``source="real"`` specs
that hash embeds the ingested matrix's content hash
(``repro.data.ingest.content_hash``), so cached trials are invalidated
when the underlying bytes change, not just when the spec does.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import glm, sgd
from repro.obs import metrics, trace
from repro.study.spec import DatasetSpec, TrialSpec, canonical_json


@dataclasses.dataclass
class TrialResult:
    """One trial's measured history (mirrors ``sgd.RunResult`` + meta)."""

    losses: np.ndarray          # [epochs+1] incl. the init loss
    epoch_times: np.ndarray     # [epochs] wall seconds
    strategy: str
    task: str
    cached: bool = False        # served from the trial cache
    stacked: bool = False       # timing amortized over a step-stack

    def epochs_to(self, target: float) -> int | None:
        hit = np.nonzero(self.losses <= target)[0]
        return int(hit[0]) if len(hit) else None

    def time_to(self, target: float) -> float | None:
        e = self.epochs_to(target)
        if e is None:
            return None
        return float(np.sum(self.epoch_times[:e]))

    @property
    def time_per_epoch(self) -> float:
        return float(np.mean(self.epoch_times))

    @property
    def final_loss(self) -> float:
        return float(self.losses[-1])

    def to_dict(self) -> dict:
        return {
            "losses": [float(x) for x in self.losses],
            "epoch_times": [float(x) for x in self.epoch_times],
            "strategy": self.strategy,
            "task": self.task,
            "stacked": self.stacked,
        }

    @classmethod
    def from_dict(cls, dct: dict, *, cached: bool = False) -> "TrialResult":
        return cls(
            losses=np.asarray(dct["losses"], dtype=np.float64),
            epoch_times=np.asarray(dct["epoch_times"], dtype=np.float64),
            strategy=dct["strategy"],
            task=dct["task"],
            cached=cached,
            stacked=dct.get("stacked", False),
        )


class TrialCache:
    """Content-addressed on-disk cache: ``<root>/<trial.key>.json``."""

    def __init__(self, root: str | Path | None):
        self.root = Path(root) if root is not None else None
        self.hits = 0
        self.misses = 0

    def get(self, key: str) -> dict | None:
        payload = self.peek(key)
        if payload is None:
            self.misses += 1
            metrics.counter("study.trial_cache.miss").inc()
        else:
            self.hits += 1
            metrics.counter("study.trial_cache.hit").inc()
        return payload

    def peek(self, key: str) -> dict | None:
        """``get`` without touching the hit/miss counters (merge re-reads)."""
        if self.root is None:
            return None
        try:
            with open(self.root / f"{key}.json") as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def put(self, key: str, payload: dict) -> None:
        if self.root is None:
            return
        self.root.mkdir(parents=True, exist_ok=True)
        tmp = self.root / f".{key}.tmp.{os.getpid()}"
        tmp.write_text(canonical_json(payload))
        tmp.replace(self.root / f"{key}.json")  # atomic on POSIX


def _problem(ds, task: str, step: float):
    """(problem, sparse_data) for one loaded dataset — the engine's input."""
    if ds.dense:
        return glm.GLMProblem(task, jnp.asarray(ds.X), jnp.asarray(ds.y),
                              step), False
    return (task, ds.ell, jnp.asarray(ds.y), step), True


def _stackable(t: TrialSpec) -> bool:
    """Kernel-backend epochs bake the step statically → no step stacking."""
    return getattr(t.strategy, "kernel_backend", None) is None


class Runner:
    """Executes trial lists with caching, stacking, and store recording."""

    def __init__(self, cache_dir: str | Path | None = None, *,
                 store=None, stack: bool = True, executor=None,
                 dispatch_min_groups: int = 2):
        self.cache = TrialCache(cache_dir)
        self.store = store
        self.stack = stack
        self.executor = executor        # validated by the property setter
        #: dispatch to the executor only when at least this many stack
        #: groups miss the cache: a single group cannot parallelize, and
        #: running it in-process skips the subprocess cold start and keeps
        #: the dataset memo warm (so `--workers` is never slower than
        #: serial on single-grid call sites)
        self.dispatch_min_groups = dispatch_min_groups
        self._datasets: dict[DatasetSpec, object] = {}

    @property
    def executor(self):
        return self._executor

    @executor.setter
    def executor(self, executor) -> None:
        # a property so post-construction attachment (benchmarks.run
        # --workers sets it on the shared runner) fails fast too
        if executor is not None and self.cache.root is None:
            raise ValueError("an executor needs a canonical cache_dir to "
                             "merge worker results into")
        self._executor = executor

    def dataset(self, dspec: DatasetSpec):
        if dspec not in self._datasets:
            self._datasets[dspec] = dspec.load()
        return self._datasets[dspec]

    # -- execution ----------------------------------------------------------

    def run_trial(self, trial: TrialSpec) -> TrialResult:
        return self.run([trial])[0]

    def run(self, trials: Sequence[TrialSpec]) -> list[TrialResult]:
        """Run every trial (cache-first), preserving input order."""
        results: list[TrialResult | None] = [None] * len(trials)
        pending: dict[str, list[int]] = {}
        for i, t in enumerate(trials):
            payload = self.cache.get(t.key)
            if payload is not None:
                results[i] = TrialResult.from_dict(payload, cached=True)
            else:
                pending.setdefault(t.stack_key, []).append(i)

        if pending and self.executor is not None \
                and len(pending) >= self.dispatch_min_groups:
            self._run_dispatched(trials, pending, results)
        else:
            for indices in pending.values():
                group = [trials[i] for i in indices]
                if self.stack and len(group) > 1 and _stackable(group[0]):
                    outs = self._run_stacked(group)
                else:
                    outs = [self._run_single(t) for t in group]
                for i, t, res in zip(indices, group, outs):
                    results[i] = res
                    self.cache.put(t.key, res.to_dict())

        for t, res in zip(trials, results):
            if self.store is not None:
                self.store.record_trial(t, res)
        return results  # type: ignore[return-value]

    def _run_dispatched(self, trials, pending, results) -> None:
        """Hand cache misses to the executor, then read the merged cache.

        The executor owns sharding, worker lifecycle, retries, and the
        cache merge; its contract is simply that every requested key is
        in the canonical cache afterwards.  Results are re-read from
        the cache (not returned in-band) so the dispatched path and the
        warm-cache path serve byte-identical payloads.
        """
        todo = [trials[i] for idxs in pending.values() for i in idxs]
        try:
            report = self.executor.execute(todo, self.cache,
                                           stack=self.stack)
        except Exception as exc:
            # a failed sweep is when attribution matters most: executors
            # attach their partial report to the failure (ShardFailure)
            self._record_exec_events(getattr(exc, "report", None))
            raise
        self._record_exec_events(report)
        for idxs in pending.values():
            for i in idxs:
                payload = self.cache.peek(trials[i].key)
                if payload is None:
                    raise RuntimeError(
                        f"executor left no payload for {trials[i].label} "
                        f"({trials[i].key})")
                # computed this sweep (by a worker), not served from cache
                results[i] = TrialResult.from_dict(payload, cached=False)

    def _record_exec_events(self, report) -> None:
        if report is None or self.store is None \
                or not hasattr(self.store, "record_event"):
            return
        for run in report.shard_runs:
            self.store.record_event("sweep_shard", **run.to_dict())
        self.store.record_event(
            "sweep_merge", executor=report.executor,
            workers=report.workers, n_trials=report.n_trials,
            retries=report.retries, **report.merge.to_dict())

    def _run_single(self, t: TrialSpec) -> TrialResult:
        with trace.span("runner.trial", key=t.key, label=t.label,
                        strategy=t.strategy.name), trace.xprof(t.label):
            ds = self.dataset(t.dataset)
            problem, sparse_data = _problem(ds, t.task, t.step)
            r = sgd.run(problem, t.strategy, t.epochs,
                        sparse_data=sparse_data)
        return TrialResult(losses=np.asarray(r.losses, dtype=np.float64),
                           epoch_times=np.asarray(r.epoch_times,
                                                  dtype=np.float64),
                           strategy=t.strategy.name, task=t.task)

    def _run_stacked(self, group: Sequence[TrialSpec]) -> list[TrialResult]:
        """One compiled program for a whole step grid (same-shape configs).

        Mirrors ``sgd.run``'s timing protocol: the first epoch includes
        compilation and its time is replaced by the median of the rest;
        stack wall time is amortized evenly over the S member trials
        (they execute fused, so per-trial attribution is 1/S by
        construction — same strategy, same shapes, same program).
        """
        base = group[0]
        S = len(group)
        metrics.histogram("study.stack_size").observe(float(S))
        with trace.span("runner.stack", size=S, label=base.label,
                        strategy=base.strategy.name), trace.xprof(base.label):
            ds = self.dataset(base.dataset)
            problem, sparse_data = _problem(ds, base.task, base.step)
            init, epoch_fn, loss_fn, _ = sgd.make_epoch_fn(
                problem, base.strategy, sparse_data=sparse_data,
                step_param=True)
            steps = jnp.asarray([t.step for t in group], dtype=jnp.float32)
            state = jnp.stack([init] * S)
            epoch_v = jax.jit(jax.vmap(epoch_fn))
            loss_v = jax.jit(jax.vmap(loss_fn))

            losses = [np.asarray(loss_v(state), dtype=np.float64)]
            times: list[float] = []
            with trace.span("engine.compile", strategy=base.strategy.name,
                            stacked=S):
                state = epoch_v(state, steps)      # warmup epoch (compiles)
                jax.block_until_ready(state)
            losses.append(np.asarray(loss_v(state), dtype=np.float64))
            times.append(float("nan"))
            for e in range(base.epochs - 1):
                with trace.span("engine.epoch", epoch=e + 1,
                                strategy=base.strategy.name, stacked=S):
                    t0 = time.perf_counter()
                    state = epoch_v(state, steps)
                    jax.block_until_ready(state)
                    times.append(time.perf_counter() - t0)
                losses.append(np.asarray(loss_v(state), dtype=np.float64))
            times[0] = (float(np.nanmedian(times[1:]))
                        if len(times) > 1 else 0.0)

        loss_mat = np.stack(losses, axis=1)              # [S, epochs+1]
        per_trial_times = np.asarray(times) / S          # amortized
        return [
            TrialResult(losses=loss_mat[i],
                        epoch_times=per_trial_times.copy(),
                        strategy=t.strategy.name, task=t.task, stacked=True)
            for i, t in enumerate(group)
        ]
