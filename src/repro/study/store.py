"""Structured results store — ``BENCH_study.json`` + per-run JSONL log.

``BENCH_study.json`` is the machine-readable perf trajectory of the
repo: every trial a sweep executed (spec + loss curve + epoch timings +
derived metrics) plus the paper-claim verdicts.  The snapshot is
serialized deterministically (sorted keys, canonical floats, no
timestamps), and trial records come from the cache on re-runs — so a
sweep whose claim checks pass re-run from a warm trial cache writes a
byte-identical file, which CI asserts.  (The claims section is the one
input that is *not* cache-derived — a micro-timing-based claim that
flips between runs changes the file, but also fails the sweep loudly
via the driver's non-zero exit, never a silent diff.)

Run-to-run variance (timestamps, cache-hit counts, wall time) lives in
the append-only JSONL sidecar — one summary line per sweep invocation,
preceded by any provenance **events** recorded during the run
(``record_event``): distributed sweeps log one ``sweep_shard`` event
per worker attempt (worker id, trial keys, wall time, requeues) and a
``sweep_merge`` event per cache merge, so the perf trajectory can
attribute wall time to workers.  Events never enter the deterministic
``BENCH_study.json`` snapshot.
"""
from __future__ import annotations

import datetime
import json
from pathlib import Path

from repro.study.spec import SCHEMA_VERSION, TrialSpec, canonical_json

#: version stamped on every JSONL sidecar *event* line ("schema" field).
#: Bump when event field semantics change; ``load_events`` refuses lines
#: stamped newer than this reader, and treats unstamped lines as legacy
#: (pre-stamping sidecars stay loadable).
EVENT_SCHEMA = 1


def load_events(path: str | Path, *,
                kinds: tuple[str, ...] | None = None) -> list[dict]:
    """Read + validate the event lines of a JSONL sidecar.

    Returns only *event* records (lines with an ``"event"`` field —
    run-summary lines are skipped), optionally filtered to ``kinds``.
    Raises ``ValueError`` on malformed JSON or an event stamped with a
    schema newer than :data:`EVENT_SCHEMA`; events with no stamp are
    accepted as legacy (schema 0).
    """
    out: list[dict] = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i}: not JSON ({e})") from None
            if "event" not in rec:
                continue        # run-summary line
            schema = rec.get("schema", 0)
            if not isinstance(schema, int) or schema > EVENT_SCHEMA:
                raise ValueError(
                    f"{path}:{i}: event schema {schema!r} is newer than "
                    f"this reader ({EVENT_SCHEMA}); upgrade repro.study")
            if kinds is None or rec["event"] in kinds:
                out.append(rec)
    return out


class StudyStore:
    """Accumulates trial results and claim verdicts, then writes them."""

    def __init__(self, json_path: str | Path = "BENCH_study.json", *,
                 jsonl_path: str | Path | None = None):
        self.json_path = Path(json_path)
        self.jsonl_path = Path(jsonl_path) if jsonl_path is not None else None
        self.trials: dict[str, dict] = {}
        self.claims: dict = {"checked_modules": [], "violations": []}
        self._n_recorded = 0
        self._n_cached = 0
        self._events: list[dict] = []

    # -- accumulation -------------------------------------------------------

    def record_trial(self, trial: TrialSpec, result) -> None:
        self._n_recorded += 1
        self._n_cached += bool(result.cached)
        self.trials[trial.key] = {
            "spec": trial.to_dict(),
            **result.to_dict(),
            "derived": {
                "final_loss": result.final_loss,
                "time_per_epoch_s": result.time_per_epoch,
            },
        }

    def record_event(self, kind: str, **fields) -> None:
        """Queue a provenance event for the JSONL sidecar (never the JSON).

        Worker attribution, shard requeues, cache merges — anything
        that varies run-to-run but explains *how* this sweep executed.
        Events are flushed (and cleared) by ``write``, one JSONL line
        each, before the run-summary line.  Each line is stamped with
        :data:`EVENT_SCHEMA` so :func:`load_events` can validate reads.
        """
        self._events.append({"event": kind, "schema": EVENT_SCHEMA,
                             **fields})

    def record_claims(self, violations: list[str],
                      checked_modules: list[str]) -> None:
        self.claims = {
            "checked_modules": sorted(checked_modules),
            "violations": sorted(violations),
        }

    # -- serialization ------------------------------------------------------

    def snapshot(self) -> dict:
        """Deterministic view: no timestamps, no cache/run metadata."""
        return {
            "schema": SCHEMA_VERSION,
            "trials": dict(sorted(self.trials.items())),
            "claims": self.claims,
        }

    def write(self) -> Path:
        self.json_path.parent.mkdir(parents=True, exist_ok=True)
        self.json_path.write_text(
            json.dumps(self.snapshot(), sort_keys=True, indent=1) + "\n")
        if self.jsonl_path is not None:
            self.jsonl_path.parent.mkdir(parents=True, exist_ok=True)
            ts = datetime.datetime.now(datetime.timezone.utc) \
                         .isoformat(timespec="seconds")
            lines = [canonical_json({"ts": ts, **ev}) for ev in self._events]
            lines.append(canonical_json({
                "ts": ts,
                "json_path": str(self.json_path),
                "n_trials": len(self.trials),
                "n_recorded": self._n_recorded,
                "n_cached": self._n_cached,
                "n_events": len(self._events),
                "n_violations": len(self.claims["violations"]),
            }))
            with open(self.jsonl_path, "a") as f:
                f.write("".join(line + "\n" for line in lines))
        self._events = []
        return self.json_path

    @staticmethod
    def load(path: str | Path) -> dict:
        with open(path) as f:
            return json.load(f)


class TrajectoryStore:
    """Shared base of the labeled-entry benchmark stores.

    The study store records *trials* (SGD runs); these siblings record
    labeled measurement entries — one dict per trajectory point — and
    serialize them with the same determinism contract as
    ``BENCH_study.json``: measured values come from an on-disk timing
    cache on re-runs, so a warm re-run writes a byte-identical file (CI
    asserts this per store).  Host-varying comparisons (regression
    gates vs the committed trajectory) stay in the claims layer and
    never enter the snapshot; run-varying events (timing dispersion,
    host notes) go to the JSONL sidecar only.
    """

    DEFAULT_PATH = "BENCH.json"

    def __init__(self, json_path: str | Path | None = None, *,
                 jsonl_path: str | Path | None = None):
        self.json_path = Path(json_path if json_path is not None
                              else self.DEFAULT_PATH)
        self.jsonl_path = Path(jsonl_path) if jsonl_path is not None else None
        self.entries: dict[str, dict] = {}
        self._n_cached = 0
        self._events: list[dict] = []

    def record_entry(self, label: str, entry: dict, *,
                     cached: bool = False) -> None:
        self._n_cached += bool(cached)
        self.entries[label] = entry

    def record_event(self, kind: str, **fields) -> None:
        """Queue a run-varying event (timing dispersion, host notes) for
        the JSONL sidecar — same contract as ``StudyStore.record_event``:
        flushed by ``write``, never into the deterministic snapshot."""
        self._events.append({"event": kind, "schema": EVENT_SCHEMA,
                             **fields})

    def snapshot(self) -> dict:
        """Deterministic view: no timestamps, no cache/run metadata."""
        return {
            "schema": SCHEMA_VERSION,
            "entries": dict(sorted(self.entries.items())),
        }

    def write(self) -> Path:
        self.json_path.parent.mkdir(parents=True, exist_ok=True)
        self.json_path.write_text(
            json.dumps(self.snapshot(), sort_keys=True, indent=1) + "\n")
        if self.jsonl_path is not None:
            self.jsonl_path.parent.mkdir(parents=True, exist_ok=True)
            ts = datetime.datetime.now(datetime.timezone.utc) \
                         .isoformat(timespec="seconds")
            lines = [canonical_json({"ts": ts, **ev}) for ev in self._events]
            lines.append(canonical_json({
                "ts": ts,
                "json_path": str(self.json_path),
                "n_entries": len(self.entries),
                "n_cached": self._n_cached,
                "n_events": len(self._events),
            }))
            with open(self.jsonl_path, "a") as f:
                f.write("".join(line + "\n" for line in lines))
        self._events = []
        return self.json_path

    @staticmethod
    def load(path: str | Path) -> dict:
        with open(path) as f:
            return json.load(f)


class KernelBenchStore(TrajectoryStore):
    """``BENCH_kernels.json`` — the kernel-level perf trajectory.

    One entry per (family, shape, dtype, block-config variant) with the
    measured wall time, the conformance verdict against the oracle, and
    the analytic roofline annotation (``repro.roofline.kernels``).
    """

    DEFAULT_PATH = "BENCH_kernels.json"


class ServeBenchStore(TrajectoryStore):
    """``BENCH_serve.json`` — the serving-layer perf trajectory.

    One entry per (batch size, sparsity) point of the GLM scoring
    service (``repro.serve.glm``): request-latency quantiles (p50/p99),
    sustained requests/s, the ``glm_score`` conformance verdict at that
    shape, and the roofline annotation of one scoring launch.
    """

    DEFAULT_PATH = "BENCH_serve.json"


class LiveBenchStore(TrajectoryStore):
    """``BENCH_live.json`` — the live (train-while-serving) trajectory.

    Two cell families per profile (``benchmarks.bench_live``):
    convergence-vs-wall-time points of the online replica-merge learner
    (holdout-loss curve at checkpoints, steps/s, merges) and
    serve-latency-under-training points (request-latency quantiles and
    throughput of the scoring engine while the learner trains and
    publishes concurrently, plus the measured staleness vs the
    publisher's guaranteed bound).
    """

    DEFAULT_PATH = "BENCH_live.json"
