"""Declarative experiment specs — the paper's study as frozen data.

The paper's methodology (§6) is a sweep: {dataset × task} × {update
strategy × replication × access path} with the step size grid-searched
per cell and every cell scored on the three performance axes.  This
module declares that sweep as hashable frozen dataclasses so the runner
can cache, stack, and resume it:

* ``DatasetSpec``   a reproducible dataset: a synthetic Table-3
                    stand-in (profile + size cap + seed), an explicit
                    (n, d) dense shape for scaling studies, or one of
                    the paper's real datasets via ``source="real"``
                    (ingested by ``repro.data.ingest``; its trial keys
                    embed the ingested content hash);
* ``DatasetProfile``the advisor-facing summary (n, d, nnz/example,
                    density) — derivable without materializing the data;
* ``TrialSpec``     one (dataset, task, strategy, step, epochs) cell with
                    a content-hash ``key`` that names its cache entry;
* ``grid``          the cross-product builder.

Strategies (``SyncSGD`` / ``AsyncLocalSGD``, incl. the kernel-backend
axis) serialize through ``strategy_to_dict`` / ``strategy_from_dict`` so
specs round-trip through the JSON store.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Iterable, Sequence

from repro.core import sgd
from repro.data import synthetic

#: bump when trial semantics change in a way that invalidates cached results
SCHEMA_VERSION = 1


# ---------------------------------------------------------------------------
# Datasets
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DatasetProfile:
    """What the advisor needs to know about a dataset without loading it."""

    name: str
    n: int
    d: int
    avg_nnz: float
    dense: bool

    @property
    def nnz_per_example(self) -> float:
        """Work per example in feature-ops (dense rows touch all of d)."""
        return float(self.d) if self.dense else float(self.avg_nnz)


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """A reproducible dataset instance, synthetic or real.

    Table-3 stand-ins: ``DatasetSpec("covtype", max_n=2048)``.  Scaling
    studies (fig24-style) pin an explicit dense shape instead:
    ``DatasetSpec("dense-d", n=1024, d=512)``.  The paper's measured
    datasets load through :mod:`repro.data.ingest` with
    ``source="real"`` (bundled fixture offline, cached full download
    when present); ``split`` then selects the §6.1 train/test partition
    (default ``"train"``).
    """

    name: str
    max_n: int | None = None
    seed: int = 0
    n: int | None = None     # explicit dense shape (overrides the profile)
    d: int | None = None
    source: str = "synthetic"       # "synthetic" | "real"
    split: str | None = None        # real only: "train" | "test" | "all"

    def __post_init__(self):
        if self.source not in ("synthetic", "real"):
            raise ValueError(f"source must be synthetic|real: {self.source!r}")
        if self.source == "real":
            from repro.data import ingest
            if self.n is not None or self.d is not None:
                raise ValueError("real datasets get their shape from the "
                                 "data; drop the explicit (n, d)")
            ingest.registry.get(self.name)   # raises on unknown names
            if self.split is not None and self.split not in ingest.SPLITS:
                raise ValueError(
                    f"split must be one of {ingest.SPLITS}: {self.split!r}")
            return
        if self.split is not None:
            raise ValueError("split only applies to source='real'")
        if (self.n is None) != (self.d is None):
            raise ValueError("explicit shapes need both n and d")
        if self.n is None and self.name not in synthetic.PAPER_DATASETS:
            raise ValueError(
                f"unknown dataset {self.name!r}; Table-3 names: "
                f"{tuple(synthetic.PAPER_DATASETS)} (or pass explicit n, d)")

    def _ingest_kwargs(self) -> dict:
        return {"split": self.split or "train", "max_n": self.max_n,
                "seed": self.seed}

    def load(self) -> synthetic.Dataset:
        if self.source == "real":
            from repro.data import ingest
            return ingest.load(self.name, **self._ingest_kwargs())
        if self.n is not None:
            return synthetic.make_dense(self.name, self.n, self.d,
                                        seed=self.seed)
        return synthetic.paper_dataset(self.name, max_n=self.max_n,
                                       seed=self.seed)

    def profile(self) -> DatasetProfile:
        if self.source == "real":
            # derived from the parsed data, not the Table-3 stand-in row
            from repro.data import ingest
            n, d, avg_nnz, dense = ingest.profile(self.name,
                                                  **self._ingest_kwargs())
            return DatasetProfile(self.name, n, d, avg_nnz, dense)
        if self.n is not None:
            return DatasetProfile(self.name, self.n, self.d, float(self.d),
                                  dense=True)
        N, d, avg_nnz, _max_nnz, dense = synthetic.PAPER_DATASETS[self.name]
        n = min(N, self.max_n) if self.max_n is not None else N
        n = max(n, 64)  # paper_dataset's size floor
        return DatasetProfile(self.name, n, d,
                              float(d) if dense else avg_nnz, dense)

    def to_dict(self) -> dict:
        dct = _prune_none(dataclasses.asdict(self))
        if dct.get("source") == "synthetic":   # default: keep keys stable
            del dct["source"]
        return dct

    def cache_key_dict(self) -> dict:
        """``to_dict`` plus, for real data, the ingested content hash.

        Trial-cache keys build on this instead of ``to_dict`` so a
        changed source file (re-fetched dataset, edited fixture)
        invalidates every cached trial computed from the old bytes.
        """
        dct = self.to_dict()
        if self.source == "real":
            from repro.data import ingest
            dct["content_hash"] = ingest.content_hash(
                self.name, **self._ingest_kwargs())
        return dct

    @classmethod
    def from_dict(cls, dct: dict) -> "DatasetSpec":
        return cls(**dct)


# ---------------------------------------------------------------------------
# Strategy (de)serialization
# ---------------------------------------------------------------------------

_STRATEGY_KINDS = {"sync": sgd.SyncSGD, "async": sgd.AsyncLocalSGD}


def strategy_to_dict(strategy) -> dict:
    for kind, cls in _STRATEGY_KINDS.items():
        if isinstance(strategy, cls):
            return {"kind": kind, **_prune_none(dataclasses.asdict(strategy))}
    raise TypeError(f"not a strategy: {strategy!r}")


def strategy_from_dict(dct: dict):
    dct = dict(dct)
    kind = dct.pop("kind")
    return _STRATEGY_KINDS[kind](**dct)


# ---------------------------------------------------------------------------
# Trials
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrialSpec:
    """One cell of the study: everything needed to reproduce one run."""

    dataset: DatasetSpec
    task: str                       # "lr" | "svm"
    strategy: object                # SyncSGD | AsyncLocalSGD
    step: float
    epochs: int
    seed: int = 0                   # reserved for stochastic strategies

    def to_dict(self) -> dict:
        return {
            "dataset": self.dataset.to_dict(),
            "task": self.task,
            "strategy": strategy_to_dict(self.strategy),
            "step": self.step,
            "epochs": self.epochs,
            "seed": self.seed,
        }

    @classmethod
    def from_dict(cls, dct: dict) -> "TrialSpec":
        return cls(
            dataset=DatasetSpec.from_dict(dct["dataset"]),
            task=dct["task"],
            strategy=strategy_from_dict(dct["strategy"]),
            step=dct["step"],
            epochs=dct["epochs"],
            seed=dct.get("seed", 0),
        )

    def _key_dict(self) -> dict:
        dct = self.to_dict()
        dct["dataset"] = self.dataset.cache_key_dict()
        return dct

    @property
    def key(self) -> str:
        """Content-hash cache key: same spec ⇒ same key across processes.

        For real datasets the key embeds the ingested matrix's content
        hash, so trials cached against stale bytes never serve a sweep
        over re-fetched data.
        """
        return _digest({"schema": SCHEMA_VERSION, **self._key_dict()})

    @property
    def stack_key(self) -> str:
        """Trials equal here except for ``step`` can run vmap-stacked."""
        dct = self._key_dict()
        dct.pop("step")
        return _digest({"schema": SCHEMA_VERSION, **dct})

    @property
    def sparse_data(self) -> bool:
        return not self.dataset.profile().dense

    def with_step(self, step: float) -> "TrialSpec":
        return dataclasses.replace(self, step=step)

    @property
    def label(self) -> str:
        return (f"{self.dataset.name}/{self.task}/{self.strategy.name}"
                f"@{self.step:g}x{self.epochs}")


def grid(
    datasets: Iterable[DatasetSpec],
    tasks: Sequence[str],
    strategies: Iterable,
    steps: Sequence[float],
    epochs: int,
    *,
    seed: int = 0,
) -> tuple[TrialSpec, ...]:
    """The paper's sweep: dataset × task × strategy × step, fixed epochs.

    Strategies whose replica count exceeds half the dataset size are
    dropped (a partition needs ≥ 2 examples), mirroring the benchmark
    modules' guard.
    """
    out = []
    for ds in datasets:
        n = ds.profile().n
        for task in tasks:
            for strat in strategies:
                replicas = getattr(strat, "replicas", 1)
                if n < replicas * 2:
                    continue
                for step in steps:
                    out.append(TrialSpec(ds, task, strat, step, epochs,
                                         seed=seed))
    return tuple(out)


# ---------------------------------------------------------------------------
# Canonical hashing
# ---------------------------------------------------------------------------


def _prune_none(dct: dict) -> dict:
    return {k: v for k, v in dct.items() if v is not None}


def canonical_json(obj) -> str:
    """Deterministic JSON: sorted keys, no whitespace drift, repr floats."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def _digest(obj) -> str:
    return hashlib.sha256(canonical_json(obj).encode()).hexdigest()[:16]
