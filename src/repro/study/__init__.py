"""Study harness: the paper's experimental methodology as a subsystem.

The paper's core contribution is a *methodology* — sweep update
strategy × replication × access path per dataset, measure hardware
efficiency, statistical efficiency, and time-to-convergence, and pick
the optimal configuration per dataset/hardware (§6, Tables 4-7).  The
modules here make that loop a first-class, cacheable API.

Dataflow (DESIGN.md §4; ingestion feeding it is §5):

    spec.TrialSpec grid ──▶ tuner.tune_step ──▶ runner.Runner ──▶ store
                                                     │
    advisor.recommend ◀── ranked Table-6 answer ◀────┘
                                         claims.validate ──▶ verdicts

Modules
-------
spec     frozen, content-hashed trial descriptions (``DatasetSpec`` —
         synthetic stand-in, explicit dense shape, or real data via
         ``source="real"`` — × task × strategy × step × epochs)
runner   cache-first execution with vmap step-stacking; attach a
         ``repro.sweep`` executor to dispatch cache misses across N
         worker processes (DESIGN.md §6)
tuner    the §6.1 step-size grid search as a reusable autotuner
         (rank ties break on canonical step order, so multi-worker and
         single-host sweeps pick identical steps)
store    deterministic ``BENCH_study.json`` + append-only run JSONL
         (incl. sweep provenance events: worker/shard/merge)
advisor  the paper's Table 6 as a queryable API (``recommend``), with
         a calibratable epoch-cost model (``calibrate``)
claims   paper-claim predicates validated against sweep rows

Quickstart
----------
Run one cached sweep cell and ask the advisor the Table-6 question::

    from repro.core import sgd
    from repro.study import advisor, spec
    from repro.study.runner import Runner

    runner = Runner(cache_dir="bench_results/study_cache")
    trial = spec.TrialSpec(
        dataset=spec.DatasetSpec("w8a", source="real"),  # bundled fixture
        task="lr", strategy=sgd.SyncSGD(), step=1e-2, epochs=8)
    result = runner.run_trial(trial)        # cached under trial.key
    print(result.final_loss, result.time_per_epoch)

    rec = advisor.recommend("covtype", task="svm", runner=runner)
    print(rec.best.name, rec.best.score)

``python -m benchmarks.run`` drives the full table/figure sweeps on
top of this package (``--real`` switches to ingested real datasets).
"""
from repro.study import advisor, claims, runner, spec, store, tuner  # noqa: F401
