"""Study harness: the paper's experimental methodology as a subsystem.

Dataflow (DESIGN.md §4):

    spec.TrialSpec grid ──▶ tuner.tune_step ──▶ runner.Runner ──▶ store
                                                     │
    advisor.recommend ◀── ranked Table-6 answer ◀────┘
                                         claims.validate ──▶ verdicts
"""
from repro.study import advisor, claims, runner, spec, store, tuner  # noqa: F401
