"""Paper-claim validation predicates (formerly inline in benchmarks/run.py).

Each ``check_*`` takes the row dicts one benchmark module produced and
returns human-readable violation strings (empty = claim holds);
``validate`` dispatches a full results dict.  Living here instead of the
benchmark driver lets tests assert the predicates directly on synthetic
rows, and lets the store persist verdicts next to the trial data.

Usage — validate sweep rows without the benchmark driver::

    from repro.study import claims

    rows = [{"dataset": "w8a", "task": "lr", "n": 2048,
             "paths_statistically_identical": True,
             "speedup_sync_vs_seq": 41.0}]
    assert claims.check_table4(rows) == []            # claim holds
    assert claims.validate({"table4_sync": rows}) == []

``benchmarks.run`` calls ``validate`` on every sweep and exits
non-zero on violations; ``store.StudyStore.record_claims`` persists
the verdicts into ``BENCH_study.json``.  Timing-based predicates carry
size/noise floors (e.g. ``TABLE4_TIMING_N_FLOOR``) so miniature
fixture runs only assert the statistical halves of each claim.
"""
from __future__ import annotations


#: below this many examples the batch-vs-sequential timing claim is
#: meaningless (fixed launch overhead dominates sub-ms epochs — the
#: regime real-data fixtures run in); statistical identity always holds
TABLE4_TIMING_N_FLOOR = 1024


def check_table4(rows: list[dict]) -> list[str]:
    """Sync statistical identity across execution paths + batch ≥ seq.

    The speedup claim is the paper's at-scale statement (§6.2, >400x on
    full datasets); rows measured on fewer than
    ``TABLE4_TIMING_N_FLOOR`` examples (miniature fixtures) only assert
    the statistical-identity half.
    """
    bad = []
    for r in rows:
        if not r["paths_statistically_identical"]:
            bad.append(f"table4: fused != composition on {r['dataset']}"
                       f"/{r['task']} (sync statistical identity broken)")
        if (r.get("n", TABLE4_TIMING_N_FLOOR) >= TABLE4_TIMING_N_FLOOR
                and r["speedup_sync_vs_seq"] < 1.0):
            bad.append(f"table4: batch path slower than sequential on "
                       f"{r['dataset']}/{r['task']}")
    return bad


def check_fig11(rows: list[dict]) -> list[str]:
    """Model replication never improves statistical efficiency (§5.2.2)."""
    bad = []
    by_key: dict[tuple, list[dict]] = {}
    for r in rows:
        by_key.setdefault((r["dataset"], r["task"]), []).append(r)
    for key, rs in by_key.items():
        rs = sorted(rs, key=lambda r: r["replicas"])
        losses = [r["final_loss"] for r in rs]
        if losses[-1] < losses[0] * 0.98:   # thread beating kernel outright
            bad.append(f"fig11: replication improved statistical efficiency "
                       f"on {key} (unexpected): {losses}")
    return bad


def check_fig14(rows: list[dict]) -> list[str]:
    """rep-k data replication costs hardware efficiency (§5.2.3)."""
    bad = []
    by_key: dict[tuple, list[dict]] = {}
    for r in rows:
        by_key.setdefault((r["dataset"], r["task"]), []).append(r)
    for key, rs in by_key.items():
        rs = sorted(rs, key=lambda r: r["rep_k"])
        # single-core CI timings are noisy at sub-ms epochs: only flag a
        # clear (>=30%) inversion of the expected rep-k hardware cost
        if rs[-1]["t_epoch_ms"] < rs[0]["t_epoch_ms"] * 0.7:
            bad.append(f"fig14: rep-10 cheaper than rep-0 on {key}")
    return bad


#: a kernel trajectory point may be this much slower than the committed
#: same-host/same-device point before the regression gate trips
KERNEL_REGRESSION_TOL = 0.20


def check_bench_kernels(rows: list[dict]) -> list[str]:
    """Kernel conformance + wall-time regression gate.

    Each row is one ``BENCH_kernels.json`` trajectory point plus the
    ephemeral ``baseline_wall_s`` the producer looked up from the
    committed trajectory (same entry label, same host, same device
    kind — cross-host timings never gate).  Three failure modes:

    * ``pallas_match is False`` — a Pallas flavor disagreed with the
      oracle at this shape;
    * every row ``None`` — no Pallas flavor was checked at all.  (The
      old predicate computed ``all({})`` per row, so a run that checked
      nothing validated as green; unchecked rows now carry ``None``
      and an entirely unchecked run is a violation.)
    * wall time more than ``KERNEL_REGRESSION_TOL`` above the
      comparable committed point.
    """
    bad = []
    for r in rows:
        if r.get("pallas_match") is False:
            bad.append(f"kernels: pallas mismatch vs oracle at "
                       f"{r.get('label', r)}")
        base = r.get("baseline_wall_s")
        wall = r.get("wall_s")
        if base and wall and wall > base * (1.0 + KERNEL_REGRESSION_TOL):
            bad.append(
                f"kernels: {r.get('label')} regressed "
                f"{100.0 * (wall / base - 1.0):.0f}% vs committed "
                f"trajectory ({wall:.3e}s vs {base:.3e}s)")
    if rows and all(r.get("pallas_match") is None for r in rows):
        bad.append("kernels: no Pallas flavor was conformance-checked "
                   "(every trajectory point is unchecked)")
    return bad


#: a serve trajectory point's p50 latency may be this much above the
#: committed same-host/same-device point before the regression gate trips
#: (request latency includes queueing, noisier than a bare kernel launch)
SERVE_REGRESSION_TOL = 0.25


def check_bench_serve(rows: list[dict]) -> list[str]:
    """Scoring-service conformance + latency/throughput gate.

    Each row is one ``BENCH_serve.json`` trajectory point plus the
    ephemeral ``baseline_p50_s`` the producer looked up from the
    committed trajectory (same entry label, same host, same device
    kind — cross-host latencies never gate).  Failure modes:

    * ``pallas_match is False`` — a Pallas flavor of ``glm_score``
      disagreed with the dense oracle at this shape;
    * every row ``None`` — no Pallas flavor was checked at all (same
      vacuous-green guard as ``check_bench_kernels``);
    * non-positive throughput, or p99 below p50 (a broken quantile
      pipeline, not a slow host);
    * p50 more than ``SERVE_REGRESSION_TOL`` above the comparable
      committed point.
    """
    bad = []
    for r in rows:
        label = r.get("label", r)
        if r.get("pallas_match") is False:
            bad.append(f"serve: glm_score pallas mismatch vs oracle at "
                       f"{label}")
        rps = r.get("rps")
        if rps is not None and rps <= 0:
            bad.append(f"serve: non-positive throughput at {label}")
        p50, p99 = r.get("p50_s"), r.get("p99_s")
        if p50 is not None and p99 is not None and p99 < p50:
            bad.append(f"serve: p99 < p50 at {label} "
                       f"({p99:.3e}s < {p50:.3e}s)")
        base = r.get("baseline_p50_s")
        if base and p50 and p50 > base * (1.0 + SERVE_REGRESSION_TOL):
            bad.append(
                f"serve: {label} p50 regressed "
                f"{100.0 * (p50 / base - 1.0):.0f}% vs committed "
                f"trajectory ({p50:.3e}s vs {base:.3e}s)")
    if rows and all(r.get("pallas_match") is None for r in rows):
        bad.append("serve: no Pallas flavor of glm_score was "
                   "conformance-checked (every trajectory point is "
                   "unchecked)")
    return bad


#: a live trajectory point's latency/wall-time may be this much above
#: the committed same-host/same-device point before the gate trips (the
#: live cells run a learner and a scorer concurrently — noisier than
#: either alone)
LIVE_REGRESSION_TOL = 0.35


def check_bench_live(rows: list[dict]) -> list[str]:
    """Live (train-while-serving) convergence + consistency gate.

    Rows are the two ``BENCH_live.json`` cell families plus the
    ephemeral ``baseline_*`` fields the producer looked up from the
    committed trajectory (same label/host/device kind — cross-host
    timings never gate).  Failure modes:

    * a convergence cell whose holdout loss did not drop by at least
      10% over the run — the online learner is not learning;
    * a serve-under-training cell whose measured staleness exceeded the
      publisher's guaranteed bound, whose served versions were not
      non-decreasing, or that never served a published (post-swap)
      model — the consistency story is broken, not just slow;
    * non-positive throughput or p99 < p50 (broken pipeline);
    * p50 (serve cells) or wall time (convergence cells) more than
      ``LIVE_REGRESSION_TOL`` above the comparable committed point;
    * vacuous-green guard: a non-empty row set missing either cell
      family entirely.
    """
    bad = []
    kinds = {r.get("kind") for r in rows}
    for r in rows:
        label = r.get("label", r)
        if r.get("kind") == "convergence":
            losses = r.get("losses") or []
            if len(losses) >= 2 and losses[-1] > 0.9 * losses[0]:
                bad.append(f"live: no convergence at {label} "
                           f"(loss {losses[0]:.4g} -> {losses[-1]:.4g})")
            sps = r.get("steps_per_s")
            if sps is not None and sps <= 0:
                bad.append(f"live: non-positive steps/s at {label}")
            base = r.get("baseline_wall_s")
            wall = r.get("wall_s")
            if base and wall and wall > base * (1.0 + LIVE_REGRESSION_TOL):
                bad.append(
                    f"live: {label} wall time regressed "
                    f"{100.0 * (wall / base - 1.0):.0f}% vs committed "
                    f"trajectory ({wall:.3e}s vs {base:.3e}s)")
        elif r.get("kind") == "serve":
            ms = r.get("max_staleness_steps")
            bound = r.get("staleness_bound_steps")
            if ms is not None and bound is not None and ms > bound:
                bad.append(f"live: staleness {ms} exceeded bound {bound} "
                           f"at {label}")
            if r.get("versions_monotone") is False:
                bad.append(f"live: served versions went backwards at "
                           f"{label}")
            if not r.get("max_version_served"):
                bad.append(f"live: never served a published model at "
                           f"{label}")
            rps = r.get("rps")
            if rps is not None and rps <= 0:
                bad.append(f"live: non-positive throughput at {label}")
            p50, p99 = r.get("p50_s"), r.get("p99_s")
            if p50 is not None and p99 is not None and p99 < p50:
                bad.append(f"live: p99 < p50 at {label} "
                           f"({p99:.3e}s < {p50:.3e}s)")
            base = r.get("baseline_p50_s")
            if base and p50 and p50 > base * (1.0 + LIVE_REGRESSION_TOL):
                bad.append(
                    f"live: {label} p50 regressed "
                    f"{100.0 * (p50 / base - 1.0):.0f}% vs committed "
                    f"trajectory ({p50:.3e}s vs {base:.3e}s)")
    if rows and "convergence" not in kinds:
        bad.append("live: no convergence cells measured "
                   "(trajectory is serve-only)")
    if rows and "serve" not in kinds:
        bad.append("live: no serve-under-training cells measured "
                   "(trajectory is learner-only)")
    return bad


def check_fig24(rows: list[dict]) -> list[str]:
    """Async time/epoch grows (sub-)linearly in N."""
    bad = []
    n_rows = [r for r in rows if r["axis"] == "N"]
    if len(n_rows) >= 2:
        t0, t1 = n_rows[0], n_rows[-1]
        growth = t1["t_epoch_async_ms"] / max(t0["t_epoch_async_ms"], 1e-9)
        size = t1["value"] / t0["value"]
        if growth > size * 3:
            bad.append(f"fig24: async time grew {growth:.1f}x for {size:.0f}x "
                       f"data (super-linear)")
    return bad


CHECKS = {
    "table4_sync": check_table4,
    "fig11_model_replication": check_fig11,
    "fig14_data_replication": check_fig14,
    "bench_kernels": check_bench_kernels,
    "bench_serve": check_bench_serve,
    "bench_live": check_bench_live,
    "fig24_scale": check_fig24,
}


def validate(results: dict[str, list[dict]]) -> list[str]:
    """Run every applicable claim check; returns all violations."""
    bad: list[str] = []
    for module, check in CHECKS.items():
        if module in results:
            bad.extend(check(results[module]))
    return bad
