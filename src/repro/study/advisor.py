"""Configuration advisor — the paper's Table 6 as a queryable API.

Table 6 answers "which configuration is optimal for this dataset on this
hardware": the winning update strategy / replication level / access path
is dataset- and hardware-dependent and must be *searched* (the same
conclusion as Parnell et al. and Keuper & Pfreundt — see PAPERS.md).

``recommend(profile, caps)`` runs that search: it builds a candidate
space filtered by the host's capabilities, tunes each candidate's step
size (§6.1), and ranks candidates by time-to-convergence

    score = epochs_to_target × epoch_cost

where ``epochs_to_target`` is *measured* statistical efficiency (from
seeded runs — deterministic) and ``epoch_cost`` is, by default, a
deterministic roofline-flavored hardware model (``modeled_epoch_cost``),
so the ranking is reproducible under a fixed seed.  ``rank="measured"``
substitutes measured wall time per epoch (the paper's actual Table-6
protocol; benchmarks use it, tests use the default), and
``rank="calibrated"`` keeps the deterministic model but with its
constants **fit to this host**: ``calibrate(store)`` least-squares the
cost model against the measured wall-times already recorded in
``BENCH_study.json`` (falling back to the fixed defaults below a
minimum trial count).  The measured evidence is attached to every
ranked row either way.

Usage — "what should I run on this dataset, on this host?"::

    from repro.study import advisor

    rec = advisor.recommend("w8a", task="lr")        # synthetic stand-in
    print(rec.best.name, rec.best.best_step)          # e.g. async-r16-b1
    for row in rec.ranked:                            # full Table-6 row set
        print(row.name, row.score, row.stat_penalty, row.hw_advantage)

Pass a ``DatasetSpec(..., source="real")`` to rank against an ingested
real dataset, a ``Runner(cache_dir=...)`` to reuse the study trial
cache across calls, and ``caps=HostCaps.detect()`` (the default) to
filter candidates by what this host can execute.  ``benchmarks/
table6_optimal.py`` is a thin wrapper over this module with
``rank="measured"``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

import numpy as np

from repro.core import convergence, sgd
from repro.study import tuner as tuner_mod
from repro.study.runner import Runner, TrialResult
from repro.study.spec import (DatasetProfile, DatasetSpec, TrialSpec,
                              strategy_to_dict)

#: cost-model constants (relative feature-op units; see modeled_epoch_cost)
UPDATE_OVERHEAD = 16.0     # fixed cost of applying one model update
MERGE_UNIT = 1.0           # per (replica × feature) cost of a merge

#: below this many measured trials, calibrate() keeps the fixed defaults
CALIBRATION_MIN_TRIALS = 8


#: per-device example-lane estimate: a TPU core's (8, 128) vregs vs a
#: handful of SIMD lanes on CPU/GPU-less hosts
_LANES_PER_DEVICE = {"tpu": 128 * 8}
_DEFAULT_LANES = 8


@dataclasses.dataclass(frozen=True)
class HostCaps:
    """What the advisor may assume about the host.

    ``parallel_width`` — how many example-lanes the host can keep busy
    simultaneously (the paper's thread/warp count analogue); replicas and
    batch rows vectorize up to this width.  ``backends`` — the kernel
    dispatch registry's available backends per family, from
    ``kernels.common.available_backends``.  ``platform`` /
    ``device_count`` record what ``detect`` saw in ``jax.devices()``
    (an attached TPU topology scales ``parallel_width`` by its device
    count) — provenance fields; the cost model reads only the width.
    """

    parallel_width: int
    max_replicas: int
    backends: Mapping[str, tuple[str, ...]]
    platform: str = "cpu"
    device_count: int = 1

    @classmethod
    def detect(cls) -> "HostCaps":
        import jax

        import repro.kernels  # noqa: F401 — registers all families
        from repro.kernels import common as kcommon

        devices = jax.devices()
        platform = devices[0].platform if devices else "cpu"
        width = _LANES_PER_DEVICE.get(platform, _DEFAULT_LANES) \
            * max(1, len(devices))
        # replica count is a *statistical* axis, not a lane budget: the vmap
        # engine emulates thread-granularity replication (R >> lanes) on any
        # host; the cost model charges the serialization, not the space.
        return cls(
            parallel_width=width,
            max_replicas=64,
            backends={
                fam: kcommon.available_backends(fam)
                for fam in ("glm_grad", "glm_sgd", "glm_sparse")
            },
            platform=platform,
            device_count=len(devices),
        )

    def to_dict(self) -> dict:
        dct = dataclasses.asdict(self)
        dct["backends"] = {k: list(v) for k, v in self.backends.items()}
        return dct


# ---------------------------------------------------------------------------
# Hardware-efficiency model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostModel:
    """The constants of ``modeled_epoch_cost``, fixed or fitted.

    The default instance reproduces the hand-picked feature-op units
    (``scale=1.0``); ``calibrate`` returns one whose constants are
    least-squares fit to measured wall-times, with ``scale`` carrying
    the feature-ops→seconds conversion for this host.  Only *ratios*
    between candidate configurations matter to the ranking either way.
    """

    update_overhead: float = UPDATE_OVERHEAD
    merge_unit: float = MERGE_UNIT
    scale: float = 1.0
    source: str = "default"         # "default" | "calibrated"
    n_trials: int = 0               # measured trials behind a fit

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


DEFAULT_COST_MODEL = CostModel()


def cost_features(profile: DatasetProfile, strategy,
                  caps: HostCaps) -> tuple[float, float, float]:
    """The cost model's linear decomposition for one configuration.

    Returns ``(base, updates, merges)`` such that

        epoch_cost = scale × (base
                              + update_overhead × updates
                              + merge_unit × merges)

    — the form both ``modeled_epoch_cost`` and the least-squares fit in
    ``calibrate`` consume.  ``base`` is the vectorized feature-op work,
    ``updates`` counts sequential model updates (each paying the fixed
    update overhead), ``merges`` counts replica-merge traffic in
    (R × d / width) units.
    """
    n, nnz, d = profile.n, profile.nnz_per_example, profile.d
    W = max(1, caps.parallel_width)
    if isinstance(strategy, sgd.SyncSGD):
        batch = strategy.batch or n
        updates = math.ceil(n / batch)
        return n * nnz / min(batch, W), float(updates), 0.0
    assert isinstance(strategy, sgd.AsyncLocalSGD)
    R = strategy.replicas
    per = n // R + strategy.rep_k
    # replicas occupy up to W lanes; leftover width vectorizes the local batch
    lanes_per_replica = max(1, W // R)
    chain = math.ceil(per / strategy.local_batch)    # sequential updates
    work = per * nnz / min(strategy.local_batch, lanes_per_replica)
    waves = math.ceil(R / W)        # more replicas than lanes ⇒ they serialize
    merges = max(1, int(round(1.0 / strategy.merge_every))) \
        if strategy.merge_every <= 1 else 1
    return (merges * work * waves, float(merges * chain * waves),
            merges * R * d / W)


def modeled_epoch_cost(profile: DatasetProfile, strategy, caps: HostCaps,
                       model: CostModel = DEFAULT_COST_MODEL) -> float:
    """Relative cost of one epoch, in feature-ops on ``caps``.

    A coarse roofline: work vectorizes up to ``parallel_width`` lanes,
    every model update pays a fixed overhead (the batch-vs-incremental
    trade), replica merges pay R×d.  With the default ``model`` the
    absolute scale is meaningless; only ratios between candidate
    configurations matter, and those reproduce the paper's qualitative
    trade-offs:

    * more replicas ⇒ smaller partitions ⇒ cheaper epochs (hardware
      efficiency up — paper Fig. 12);
    * rep-k halos ⇒ each replica processes k extra examples (Fig. 15);
    * full-batch sync ⇒ one update per epoch, fully vectorized — the
      cheapest pass but the least statistically efficient (Fig. 22).

    A ``calibrate``d model keeps the same structure but host-fitted
    constants (and a seconds scale), per Shi et al.'s finding that
    configuration rankings need per-host cost calibration.
    """
    base, updates, merges = cost_features(profile, strategy, caps)
    return model.scale * (base + model.update_overhead * updates
                          + model.merge_unit * merges)


def calibrate(store, caps: HostCaps | None = None, *,
              min_trials: int = CALIBRATION_MIN_TRIALS) -> CostModel:
    """Fit the cost model's constants to measured wall-times in a store.

    ``store`` is a ``StudyStore``, a loaded snapshot dict, or a path to
    ``BENCH_study.json`` — anything holding trial records (spec +
    ``derived.time_per_epoch_s``).  Each usable trial contributes one
    least-squares row ``t ≈ k·base + k·U·updates + k·M·merges`` (linear
    in ``(k, k·U, k·M)``); the solve is ``np.linalg.lstsq`` —
    deterministic for fixed inputs.

    Falls back to ``DEFAULT_COST_MODEL`` (the fixed constants) whenever
    the fit would not be trustworthy: fewer than ``min_trials`` usable
    measured trials, or a degenerate/non-physical solution (non-positive
    scale).  Negative fitted constants clamp to 0 — a host where merges
    are free is plausible; one where they pay you is not.

    A record only contributes if its stored key matches the key this
    host recomputes from the spec — for real datasets that key embeds
    the ingested content hash, so wall-times measured against different
    bytes (a store from a full-download host calibrated against the
    bundled fixtures, say) are skipped rather than fit against the
    wrong (n, d, nnz) features.
    """
    caps = caps or HostCaps.detect()
    profiles: dict = {}
    rows: list[tuple[float, float, float]] = []
    times: list[float] = []
    for key, rec in _store_trials(store):
        try:
            trial = TrialSpec.from_dict(rec["spec"])
            t = float(rec["derived"]["time_per_epoch_s"])
            if trial.key != key:
                continue        # measured against data this host doesn't have
        except (KeyError, TypeError, ValueError, OSError):
            # OSError: a real dataset whose bytes this host cannot resolve
            # at all (no cached download, no fixture) — skip, don't abort
            continue
        if not (math.isfinite(t) and t > 0):
            continue
        if trial.dataset not in profiles:
            profiles[trial.dataset] = trial.dataset.profile()
        rows.append(cost_features(profiles[trial.dataset], trial.strategy,
                                  caps))
        times.append(t)
    if len(rows) < min_trials:
        return DEFAULT_COST_MODEL
    A = np.asarray(rows, dtype=np.float64)
    b = np.asarray(times, dtype=np.float64)
    coef, _, rank, _ = np.linalg.lstsq(A, b, rcond=None)
    k, ku, km = (float(c) for c in coef)
    if rank < A.shape[1] or k <= 0 or not math.isfinite(k):
        return DEFAULT_COST_MODEL
    return CostModel(update_overhead=max(0.0, ku / k),
                     merge_unit=max(0.0, km / k),
                     scale=k, source="calibrated", n_trials=len(rows))


def _store_trials(store) -> list[tuple[str, dict]]:
    """(key, record) pairs from a StudyStore, a snapshot dict, or a path."""
    from repro.study.store import StudyStore

    if isinstance(store, (str, bytes)) or hasattr(store, "__fspath__"):
        store = StudyStore.load(store)
    if isinstance(store, StudyStore):
        return list(store.trials.items())
    return list(store.get("trials", {}).items())


# ---------------------------------------------------------------------------
# Candidate space
# ---------------------------------------------------------------------------


def candidate_space(
    profile: DatasetProfile,
    caps: HostCaps,
    *,
    replicas: Sequence[int] = (4, 16, 64),
    accesses: Sequence[str] = ("chunk", "round_robin"),
    rep_ks: Sequence[int] = (0, 10),
    kernel_backends: Sequence[str | None] = (None, "pallas-interpret"),
) -> list:
    """Table-6 design space, filtered to what host + dataset can run."""
    out: list = []
    for kb in kernel_backends:
        if kb is not None:
            # forcing a backend bypasses the dispatch Caps checks, so the
            # space must self-limit: kernel-backed sync epochs are dense
            # glm_grad calls, and interpret-mode sparse is far too slow
            if not profile.dense:
                continue
            if kb not in caps.backends.get("glm_grad", ()):
                continue
        out.append(sgd.SyncSGD(kernel_backend=kb))
    for r in replicas:
        if r > caps.max_replicas or profile.n < r * 2:
            continue
        for access in accesses:
            for rep_k in rep_ks:
                if rep_k >= profile.n // r:
                    continue  # halo would exceed the partition itself
                out.append(sgd.AsyncLocalSGD(replicas=r, local_batch=1,
                                             access=access, rep_k=rep_k))
    return out


# ---------------------------------------------------------------------------
# Recommendation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RankedConfig:
    """One row of the recommendation table, with measured evidence."""

    strategy: object                    # the strategy dataclass itself
    score: float                        # epochs_to × epoch_cost (lower wins)
    epochs_to_target: int | None        # measured statistical efficiency
    epoch_cost: float                   # modeled (or measured s/epoch)
    best_step: float
    stat_penalty: float                 # epochs_to / best epochs_to seen
    hw_advantage: float                 # cheapest epoch_cost / own epoch_cost
    measured_time_per_epoch_s: float
    measured_time_to_target_s: float | None
    final_loss: float

    @property
    def name(self) -> str:
        return self.strategy.name

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["strategy"] = strategy_to_dict(self.strategy)
        d["name"] = self.name
        return d


@dataclasses.dataclass
class Recommendation:
    """Ranked configuration table for one (dataset, task) cell."""

    dataset: str
    task: str
    target: float                       # loss target (1% above optimum)
    rank_by: str                        # "modeled" | "measured"
    ranked: list[RankedConfig]          # best first

    @property
    def best(self) -> RankedConfig:
        return self.ranked[0]

    def to_dict(self) -> dict:
        return {
            "dataset": self.dataset,
            "task": self.task,
            "target": self.target,
            "rank_by": self.rank_by,
            "ranked": [r.to_dict() for r in self.ranked],
        }


def recommend(
    profile: DatasetProfile | DatasetSpec | str,
    caps: HostCaps | None = None,
    *,
    task: str = "lr",
    runner: Runner | None = None,
    space: Sequence | None = None,
    steps: Sequence[float] = (1e-3, 1e-2, 1e-1),
    epochs: int = 8,
    tolerance: float = 0.01,
    seed: int = 0,
    rank: str = "modeled",
    cost_model: "CostModel | object | None" = None,
) -> Recommendation:
    """Answer the paper's Table-6 question for one dataset/host/task.

    Runs the candidate space (step-tuned per §6.1) on a synthetic
    instance matching ``profile`` and returns configurations ranked by
    projected time-to-convergence.  Deterministic under a fixed seed with
    the default ``rank="modeled"``; ``rank="measured"`` uses wall time
    per epoch instead of the cost model (the benchmark protocol);
    ``rank="calibrated"`` ranks with host-fitted cost constants — pass
    ``cost_model=calibrate(store)`` (or the store/path itself, which is
    calibrated in place; omitted, it falls back to the fixed defaults,
    mirroring ``calibrate``'s own too-few-trials fallback).
    """
    if rank not in ("modeled", "measured", "calibrated"):
        raise ValueError(f"rank must be modeled|measured|calibrated: {rank!r}")
    if cost_model is not None and rank != "calibrated":
        raise ValueError(
            f"cost_model is only consulted with rank='calibrated' "
            f"(got rank={rank!r}); drop it or set the rank")
    if isinstance(profile, str):
        dspec = DatasetSpec(profile, seed=seed)
    elif isinstance(profile, DatasetSpec):
        dspec = profile  # the spec's own seed wins: keep cache keys aligned
    else:
        dspec = DatasetSpec(profile.name, max_n=profile.n, seed=seed)
    prof = dspec.profile()
    caps = caps or HostCaps.detect()
    runner = runner or Runner()
    space = list(space) if space is not None else candidate_space(prof, caps)
    if not space:
        raise ValueError(f"empty candidate space for {prof}")
    if rank == "calibrated":
        if cost_model is None:
            model = DEFAULT_COST_MODEL
        elif isinstance(cost_model, CostModel):
            model = cost_model
        else:       # a store / snapshot / path: calibrate it here
            model = calibrate(cost_model, caps)
    else:
        model = DEFAULT_COST_MODEL
    rank_by_run = "time" if rank == "measured" else "epochs"

    # one batched dispatch for the whole candidate space: with a sweep
    # executor attached, every candidate's step grid fans out at once
    bases = [TrialSpec(dataset=dspec, task=task, strategy=strat,
                       step=steps[0], epochs=epochs, seed=seed)
             for strat in space]
    tuned = list(zip(space, tuner_mod.tune_many(
        runner, bases, steps=steps, by=rank_by_run)))

    # common target: within `tolerance` of the best loss seen anywhere
    all_results: list[TrialResult] = [
        r for _, t in tuned for r in t.results.values()]
    opt = convergence.optimal_loss(all_results)
    target = convergence.thresholds(opt, (tolerance,))[tolerance]

    rows: list[RankedConfig] = []
    for strat, t in tuned:
        res = t.best_result
        e = res.epochs_to(target)
        cost = (res.time_per_epoch if rank == "measured"
                else modeled_epoch_cost(prof, strat, caps, model=model))
        score = (e * cost) if e is not None else math.inf
        rows.append(RankedConfig(
            strategy=strat, score=score, epochs_to_target=e, epoch_cost=cost,
            best_step=t.best_step, stat_penalty=0.0, hw_advantage=0.0,
            measured_time_per_epoch_s=res.time_per_epoch,
            measured_time_to_target_s=res.time_to(target),
            final_loss=res.final_loss,
        ))

    best_epochs = min((r.epochs_to_target for r in rows
                       if r.epochs_to_target is not None), default=None)
    min_cost = min(r.epoch_cost for r in rows)
    for r in rows:
        if best_epochs is not None and r.epochs_to_target is not None:
            r.stat_penalty = r.epochs_to_target / max(best_epochs, 1)
        else:
            r.stat_penalty = math.inf
        r.hw_advantage = min_cost / r.epoch_cost

    # deterministic total order: score, then final loss, then name
    rows.sort(key=lambda r: (r.score, r.final_loss, r.name))
    return Recommendation(dataset=prof.name, task=task, target=target,
                          rank_by=rank, ranked=rows)
