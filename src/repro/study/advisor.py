"""Configuration advisor — the paper's Table 6 as a queryable API.

Table 6 answers "which configuration is optimal for this dataset on this
hardware": the winning update strategy / replication level / access path
is dataset- and hardware-dependent and must be *searched* (the same
conclusion as Parnell et al. and Keuper & Pfreundt — see PAPERS.md).

``recommend(profile, caps)`` runs that search: it builds a candidate
space filtered by the host's capabilities, tunes each candidate's step
size (§6.1), and ranks candidates by time-to-convergence

    score = epochs_to_target × epoch_cost

where ``epochs_to_target`` is *measured* statistical efficiency (from
seeded runs — deterministic) and ``epoch_cost`` is, by default, a
deterministic roofline-flavored hardware model (``modeled_epoch_cost``),
so the ranking is reproducible under a fixed seed.  ``rank="measured"``
substitutes measured wall time per epoch (the paper's actual Table-6
protocol; benchmarks use it, tests use the default).  The measured
evidence is attached to every ranked row either way.

Usage — "what should I run on this dataset, on this host?"::

    from repro.study import advisor

    rec = advisor.recommend("w8a", task="lr")        # synthetic stand-in
    print(rec.best.name, rec.best.best_step)          # e.g. async-r16-b1
    for row in rec.ranked:                            # full Table-6 row set
        print(row.name, row.score, row.stat_penalty, row.hw_advantage)

Pass a ``DatasetSpec(..., source="real")`` to rank against an ingested
real dataset, a ``Runner(cache_dir=...)`` to reuse the study trial
cache across calls, and ``caps=HostCaps.detect()`` (the default) to
filter candidates by what this host can execute.  ``benchmarks/
table6_optimal.py`` is a thin wrapper over this module with
``rank="measured"``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

from repro.core import convergence, sgd
from repro.study import tuner as tuner_mod
from repro.study.runner import Runner, TrialResult
from repro.study.spec import (DatasetProfile, DatasetSpec, TrialSpec,
                              strategy_to_dict)

#: cost-model constants (relative feature-op units; see modeled_epoch_cost)
UPDATE_OVERHEAD = 16.0     # fixed cost of applying one model update
MERGE_UNIT = 1.0           # per (replica × feature) cost of a merge


@dataclasses.dataclass(frozen=True)
class HostCaps:
    """What the advisor may assume about the host.

    ``parallel_width`` — how many example-lanes the host can keep busy
    simultaneously (the paper's thread/warp count analogue); replicas and
    batch rows vectorize up to this width.  ``backends`` — the kernel
    dispatch registry's available backends per family, from
    ``kernels.common.available_backends``.
    """

    parallel_width: int
    max_replicas: int
    backends: Mapping[str, tuple[str, ...]]

    @classmethod
    def detect(cls) -> "HostCaps":
        import repro.kernels  # noqa: F401 — registers all families
        from repro.kernels import common as kcommon

        width = 128 * 8 if kcommon.on_tpu() else 8
        # replica count is a *statistical* axis, not a lane budget: the vmap
        # engine emulates thread-granularity replication (R >> lanes) on any
        # host; the cost model charges the serialization, not the space.
        return cls(
            parallel_width=width,
            max_replicas=64,
            backends={
                fam: kcommon.available_backends(fam)
                for fam in ("glm_grad", "glm_sgd", "glm_sparse")
            },
        )


# ---------------------------------------------------------------------------
# Hardware-efficiency model
# ---------------------------------------------------------------------------


def modeled_epoch_cost(profile: DatasetProfile, strategy,
                       caps: HostCaps) -> float:
    """Relative cost of one epoch, in feature-ops on ``caps``.

    A coarse roofline: work vectorizes up to ``parallel_width`` lanes,
    every model update pays a fixed overhead (the batch-vs-incremental
    trade), replica merges pay R×d.  The absolute scale is meaningless;
    only ratios between candidate configurations matter, and those
    reproduce the paper's qualitative trade-offs:

    * more replicas ⇒ smaller partitions ⇒ cheaper epochs (hardware
      efficiency up — paper Fig. 12);
    * rep-k halos ⇒ each replica processes k extra examples (Fig. 15);
    * full-batch sync ⇒ one update per epoch, fully vectorized — the
      cheapest pass but the least statistically efficient (Fig. 22).
    """
    n, nnz, d = profile.n, profile.nnz_per_example, profile.d
    W = max(1, caps.parallel_width)
    if isinstance(strategy, sgd.SyncSGD):
        batch = strategy.batch or n
        updates = math.ceil(n / batch)
        return n * nnz / min(batch, W) + updates * UPDATE_OVERHEAD
    assert isinstance(strategy, sgd.AsyncLocalSGD)
    R = strategy.replicas
    per = n // R + strategy.rep_k
    # replicas occupy up to W lanes; leftover width vectorizes the local batch
    lanes_per_replica = max(1, W // R)
    chain = math.ceil(per / strategy.local_batch)    # sequential updates
    work = per * nnz / min(strategy.local_batch, lanes_per_replica)
    replica_work = work + chain * UPDATE_OVERHEAD
    waves = math.ceil(R / W)        # more replicas than lanes ⇒ they serialize
    merges = max(1, int(round(1.0 / strategy.merge_every))) \
        if strategy.merge_every <= 1 else 1
    return merges * (replica_work * waves + MERGE_UNIT * R * d / W)


# ---------------------------------------------------------------------------
# Candidate space
# ---------------------------------------------------------------------------


def candidate_space(
    profile: DatasetProfile,
    caps: HostCaps,
    *,
    replicas: Sequence[int] = (4, 16, 64),
    accesses: Sequence[str] = ("chunk", "round_robin"),
    rep_ks: Sequence[int] = (0, 10),
    kernel_backends: Sequence[str | None] = (None,),
) -> list:
    """Table-6 design space, filtered to what host + dataset can run."""
    out: list = []
    for kb in kernel_backends:
        if kb is not None and kb not in caps.backends.get("glm_grad", ()):
            continue
        out.append(sgd.SyncSGD(kernel_backend=kb))
    for r in replicas:
        if r > caps.max_replicas or profile.n < r * 2:
            continue
        for access in accesses:
            for rep_k in rep_ks:
                if rep_k >= profile.n // r:
                    continue  # halo would exceed the partition itself
                out.append(sgd.AsyncLocalSGD(replicas=r, local_batch=1,
                                             access=access, rep_k=rep_k))
    return out


# ---------------------------------------------------------------------------
# Recommendation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RankedConfig:
    """One row of the recommendation table, with measured evidence."""

    strategy: object                    # the strategy dataclass itself
    score: float                        # epochs_to × epoch_cost (lower wins)
    epochs_to_target: int | None        # measured statistical efficiency
    epoch_cost: float                   # modeled (or measured s/epoch)
    best_step: float
    stat_penalty: float                 # epochs_to / best epochs_to seen
    hw_advantage: float                 # cheapest epoch_cost / own epoch_cost
    measured_time_per_epoch_s: float
    measured_time_to_target_s: float | None
    final_loss: float

    @property
    def name(self) -> str:
        return self.strategy.name

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["strategy"] = strategy_to_dict(self.strategy)
        d["name"] = self.name
        return d


@dataclasses.dataclass
class Recommendation:
    """Ranked configuration table for one (dataset, task) cell."""

    dataset: str
    task: str
    target: float                       # loss target (1% above optimum)
    rank_by: str                        # "modeled" | "measured"
    ranked: list[RankedConfig]          # best first

    @property
    def best(self) -> RankedConfig:
        return self.ranked[0]

    def to_dict(self) -> dict:
        return {
            "dataset": self.dataset,
            "task": self.task,
            "target": self.target,
            "rank_by": self.rank_by,
            "ranked": [r.to_dict() for r in self.ranked],
        }


def recommend(
    profile: DatasetProfile | DatasetSpec | str,
    caps: HostCaps | None = None,
    *,
    task: str = "lr",
    runner: Runner | None = None,
    space: Sequence | None = None,
    steps: Sequence[float] = (1e-3, 1e-2, 1e-1),
    epochs: int = 8,
    tolerance: float = 0.01,
    seed: int = 0,
    rank: str = "modeled",
) -> Recommendation:
    """Answer the paper's Table-6 question for one dataset/host/task.

    Runs the candidate space (step-tuned per §6.1) on a synthetic
    instance matching ``profile`` and returns configurations ranked by
    projected time-to-convergence.  Deterministic under a fixed seed with
    the default ``rank="modeled"``; ``rank="measured"`` uses wall time
    per epoch instead of the cost model (the benchmark protocol).
    """
    if isinstance(profile, str):
        dspec = DatasetSpec(profile, seed=seed)
    elif isinstance(profile, DatasetSpec):
        dspec = profile  # the spec's own seed wins: keep cache keys aligned
    else:
        dspec = DatasetSpec(profile.name, max_n=profile.n, seed=seed)
    prof = dspec.profile()
    caps = caps or HostCaps.detect()
    runner = runner or Runner()
    space = list(space) if space is not None else candidate_space(prof, caps)
    if not space:
        raise ValueError(f"empty candidate space for {prof}")
    rank_by_run = "epochs" if rank == "modeled" else "time"

    tuned: list[tuple[object, tuner_mod.TuneResult]] = []
    for strat in space:
        base = TrialSpec(dataset=dspec, task=task, strategy=strat,
                         step=steps[0], epochs=epochs, seed=seed)
        tuned.append((strat, tuner_mod.tune_step(
            runner, base, steps=steps, by=rank_by_run)))

    # common target: within `tolerance` of the best loss seen anywhere
    all_results: list[TrialResult] = [
        r for _, t in tuned for r in t.results.values()]
    opt = convergence.optimal_loss(all_results)
    target = convergence.thresholds(opt, (tolerance,))[tolerance]

    rows: list[RankedConfig] = []
    for strat, t in tuned:
        res = t.best_result
        e = res.epochs_to(target)
        cost = (modeled_epoch_cost(prof, strat, caps) if rank == "modeled"
                else res.time_per_epoch)
        score = (e * cost) if e is not None else math.inf
        rows.append(RankedConfig(
            strategy=strat, score=score, epochs_to_target=e, epoch_cost=cost,
            best_step=t.best_step, stat_penalty=0.0, hw_advantage=0.0,
            measured_time_per_epoch_s=res.time_per_epoch,
            measured_time_to_target_s=res.time_to(target),
            final_loss=res.final_loss,
        ))

    best_epochs = min((r.epochs_to_target for r in rows
                       if r.epochs_to_target is not None), default=None)
    min_cost = min(r.epoch_cost for r in rows)
    for r in rows:
        if best_epochs is not None and r.epochs_to_target is not None:
            r.stat_penalty = r.epochs_to_target / max(best_epochs, 1)
        else:
            r.stat_penalty = math.inf
        r.hw_advantage = min_cost / r.epoch_cost

    # deterministic total order: score, then final loss, then name
    rows.sort(key=lambda r: (r.score, r.final_loss, r.name))
    return Recommendation(dataset=prof.name, task=task, target=target,
                          rank_by=rank, ranked=rows)
