"""Step-size autotuner — the paper's §6.1 grid search as a reusable API.

The paper tunes the step size per (dataset, task, configuration) cell by
"griding its range in powers of 10" and keeping the fastest
time-to-convergence.  ``tune_step`` lifts that loop out of the call
sites: it expands a base ``TrialSpec`` over a step grid, executes it
through a ``Runner`` (so the grid is vmap-stacked into one compiled
program and every run lands in the trial cache), and applies the
``convergence.rank_key`` selection rule.

``by="epochs"`` ranks on statistical efficiency only — no wall-clock in
the decision — which is what makes the advisor deterministic under a
fixed seed.  Benchmarks rank ``by="time"`` like the paper.

``tune_many`` tunes several base specs through **one** ``runner.run``
call — semantically identical to mapping ``tune_step``, but the union
of the step grids is dispatched together, so an attached sweep
executor (``repro.sweep``) sees every (base × step) stack group at
once and can spread them across workers.  The advisor tunes its whole
candidate space this way.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import convergence
from repro.obs import trace
from repro.study.runner import Runner, TrialResult
from repro.study.spec import TrialSpec


@dataclasses.dataclass
class TuneResult:
    best: TrialSpec
    best_result: TrialResult
    target: float                       # the loss target used for ranking
    results: dict[float, TrialResult]   # step -> result (the whole grid)

    @property
    def best_step(self) -> float:
        return self.best.step


def tune_step(
    runner: Runner,
    base: TrialSpec,
    *,
    steps: Sequence[float] | None = None,
    target: float | None = None,
    tolerance: float = 0.01,
    by: str = "time",
) -> TuneResult:
    """Grid-search the step size of ``base`` (its own ``step`` is ignored).

    When ``target`` is None it is derived the paper's way: the lowest
    loss any grid member reached, within ``tolerance`` (default 1%).
    """
    return tune_many(runner, [base], steps=steps, target=target,
                     tolerance=tolerance, by=by)[0]


def tune_many(
    runner: Runner,
    bases: Sequence[TrialSpec],
    *,
    steps: Sequence[float] | None = None,
    target: float | None = None,
    tolerance: float = 0.01,
    by: str = "time",
) -> list[TuneResult]:
    """Tune every base spec's step size in one ``runner.run`` dispatch.

    Equivalent to ``[tune_step(runner, b, ...) for b in bases]`` — each
    base derives its target from its own grid when ``target`` is None —
    but all (base × step) trials execute in a single runner call, which
    is what lets a sweep executor fan the grids out across workers.
    """
    steps = list(steps) if steps is not None else convergence.grid_step_sizes()
    trials = [b.with_step(s) for b in bases for s in steps]
    with trace.span("study.tune", bases=len(bases), steps=len(steps),
                    by=by):
        results = runner.run(trials)
    out: list[TuneResult] = []
    for i, base in enumerate(bases):
        grid = results[i * len(steps):(i + 1) * len(steps)]
        by_step = dict(zip(steps, grid))
        tgt = target
        if tgt is None:
            opt = convergence.optimal_loss(grid)
            tgt = convergence.thresholds(opt, (tolerance,))[tolerance]
        # rank ties break on the canonical step order (smallest step wins),
        # never on grid/cache arrival order — multi-worker and single-host
        # sweeps must pick identical steps from identical results
        best_step = min(
            steps,
            key=lambda s, t=tgt: (*convergence.rank_key(by_step[s], t, by=by),
                                  s))
        out.append(TuneResult(best=base.with_step(best_step),
                              best_result=by_step[best_step],
                              target=tgt, results=by_step))
    return out
