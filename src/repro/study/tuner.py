"""Step-size autotuner — the paper's §6.1 grid search as a reusable API.

The paper tunes the step size per (dataset, task, configuration) cell by
"griding its range in powers of 10" and keeping the fastest
time-to-convergence.  ``tune_step`` lifts that loop out of the call
sites: it expands a base ``TrialSpec`` over a step grid, executes it
through a ``Runner`` (so the grid is vmap-stacked into one compiled
program and every run lands in the trial cache), and applies the
``convergence.rank_key`` selection rule.

``by="epochs"`` ranks on statistical efficiency only — no wall-clock in
the decision — which is what makes the advisor deterministic under a
fixed seed.  Benchmarks rank ``by="time"`` like the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import convergence
from repro.study.runner import Runner, TrialResult
from repro.study.spec import TrialSpec


@dataclasses.dataclass
class TuneResult:
    best: TrialSpec
    best_result: TrialResult
    target: float                       # the loss target used for ranking
    results: dict[float, TrialResult]   # step -> result (the whole grid)

    @property
    def best_step(self) -> float:
        return self.best.step


def tune_step(
    runner: Runner,
    base: TrialSpec,
    *,
    steps: Sequence[float] | None = None,
    target: float | None = None,
    tolerance: float = 0.01,
    by: str = "time",
) -> TuneResult:
    """Grid-search the step size of ``base`` (its own ``step`` is ignored).

    When ``target`` is None it is derived the paper's way: the lowest
    loss any grid member reached, within ``tolerance`` (default 1%).
    """
    steps = list(steps) if steps is not None else convergence.grid_step_sizes()
    trials = [base.with_step(s) for s in steps]
    results = runner.run(trials)
    by_step = dict(zip(steps, results))
    if target is None:
        opt = convergence.optimal_loss(results)
        target = convergence.thresholds(opt, (tolerance,))[tolerance]
    best_step = min(
        steps, key=lambda s: convergence.rank_key(by_step[s], target, by=by))
    return TuneResult(best=base.with_step(best_step),
                      best_result=by_step[best_step],
                      target=target, results=by_step)
