"""Roofline analysis over the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, derive the three roofline terms from the
compiled partitioned HLO (see hlo.py for why the text is parsed rather than
trusting cost_analysis):

    compute_s    = dot_FLOPs_per_device / MXU peak      (197e12 bf16)
    memory_s     = HBM traffic proxy    / HBM bandwidth (819e9)
    collective_s = wire bytes per device / ICI bandwidth (45e9 effective)

plus MODEL_FLOPS (6*N_active*D train / 2*N_active*D inference), the useful-
compute ratio, the dominant term, and a one-line hillclimb suggestion.

CPU-backend caveats (documented in EXPERIMENTS.md §Methodology):
  * the host backend upcasts bf16 dot inputs to f32 — FLOPs are attributed
    at the bf16 MXU rate the TPU lowering would use, and the memory/
    collective byte totals are scaled by the measured f32/bf16 inflation
    on parameter-derived buffers (none: we report raw parsed bytes and note
    the ~2x inflation where it applies);
  * Pallas kernels don't lower on the host backend; the XLA chunked paths
    analyzed here are the kernels' fallback implementations, so kernel-side
    wins (flash attention VMEM reuse) are called out as deltas, not measured.

Usage:
    PYTHONPATH=src python -m repro.roofline.analysis [--mesh 16x16]
writes roofline.json + a markdown table to dryrun_results/.
"""
from __future__ import annotations

import argparse
import gzip
import json
import math
from pathlib import Path

PEAK_FLOPS = 197e12          # bf16 MXU per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 45e9                # effective bytes/s per link (of ~50 peak)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "dryrun_results"


def param_counts(arch: str) -> tuple[float, float]:
    """(total, active-per-token) parameter counts, from real init shapes."""
    import jax

    from repro import configs
    from repro.launch import specs as specs_mod

    cfg = configs.get(arch)
    shapes, _ = specs_mod.param_shapes_and_specs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = active = 0.0
    for path, leaf in flat:
        keys = [str(getattr(k, "key", "")) for k in path]
        n = math.prod(leaf.shape)
        total += n
        if cfg.moe_experts and "moe" in keys and any(
                k in ("w_up", "w_gate", "w_down") for k in keys):
            active += n * (cfg.moe_top_k / cfg.moe_experts)
        else:
            active += n
    return total, active


def model_flops(arch: str, kind: str, seq: int, gb: int) -> float:
    """Global MODEL_FLOPS per step: 6*N_active*D (train), 2*N_active*D
    (inference); D = tokens touched this step."""
    _, active = param_counts(arch)
    tokens = gb * (1 if kind == "decode" else seq)
    return (6.0 if kind == "train" else 2.0) * active * tokens


def analyze_cell(json_path: Path) -> dict | None:
    from repro.roofline import hlo

    meta = json.loads(json_path.read_text())
    if meta.get("status") != "ok":
        return {"arch": meta.get("arch"), "shape": meta.get("shape"),
                "mesh": meta.get("mesh"), "status": "fail"}
    hlo_file = meta.get("hlo_file")
    if not hlo_file or not Path(hlo_file).exists():
        return None
    text = gzip.open(hlo_file, "rt").read()
    a = hlo.analyze(text)
    n_dev = 512 if meta["mesh"] == "2x16x16" else 256

    compute_s = a.dot_flops / PEAK_FLOPS
    memory_s = a.memory_bytes / HBM_BW
    coll_s = a.collective_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(meta["arch"], meta["kind"], meta["seq"],
                     meta["global_batch"])
    mf_dev = mf / n_dev
    ratio = mf_dev / a.dot_flops if a.dot_flops else 0.0

    suggestions = {
        "compute_s": ("cut non-model FLOPs: causal-skip attention blocks, "
                      "drop remat recompute via selective checkpoint policy"),
        "memory_s": ("raise arithmetic intensity: larger q-chunks (fewer "
                     "K/V re-reads), bf16 intermediates, flash-attn kernel "
                     "keeps K/V tiles in VMEM on TPU"),
        "collective_s": ("hoist K/V all-gathers out of the q-chunk scan, "
                         "overlap grad all-reduce with backward, or shard "
                         "activations less aggressively"),
    }

    return {
        "arch": meta["arch"], "shape": meta["shape"], "mesh": meta["mesh"],
        "kind": meta["kind"], "status": "ok",
        "devices": n_dev,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "step_time_bound_s": max(terms.values()),
        "roofline_fraction": (compute_s / max(terms.values())
                              if max(terms.values()) else 0.0),
        "model_flops_global": mf, "model_flops_per_dev": mf_dev,
        "hlo_dot_flops_per_dev": a.dot_flops,
        "useful_compute_ratio": ratio,
        "collective_by_kind": a.collective_by_kind,
        "n_while": a.n_while,
        "peak_bytes": meta["memory"].get("peak_bytes"),
        "argument_bytes": meta["memory"].get("argument_bytes"),
        "suggestion": suggestions[dominant],
    }


def run(mesh: str = "16x16", pattern: str = "*"):
    rows = []
    for p in sorted(RESULTS_DIR.glob(f"{pattern}__{mesh}.json")):
        r = analyze_cell(p)
        if r:
            rows.append(r)
            print(f"{r['arch']:22s} {r['shape']:12s} "
                  f"dom={r.get('dominant','-'):13s} "
                  f"C={r.get('compute_s',0):8.3f}s "
                  f"M={r.get('memory_s',0):8.3f}s "
                  f"X={r.get('collective_s',0):8.3f}s "
                  f"useful={r.get('useful_compute_ratio',0):5.2f}",
                  flush=True)
    out = RESULTS_DIR / f"roofline_{mesh}.json"
    out.write_text(json.dumps(rows, indent=1))
    print(f"wrote {out}")
    return rows


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| model TFLOPs/dev | useful ratio | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.get("status") != "ok":
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} "
            f"| {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| **{r['dominant'].replace('_s','')}** "
            f"| {r['model_flops_per_dev']/1e12:.2f} "
            f"| {r['useful_compute_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} |")
    return hdr + "\n".join(lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--pattern", default="*")
    args = ap.parse_args()
    rows = run(args.mesh, args.pattern)
    md = to_markdown(rows)
    (RESULTS_DIR / f"roofline_{args.mesh}.md").write_text(md)
