"""Analytic roofline annotations for the kernel microbenchmarks.

``analysis.py`` derives rooflines from compiled HLO artifacts; the kernel
microbenchmarks (benchmarks/bench_kernels.py) need the same three-term
framing for shapes that are *parameters*, not artifacts.  This module
prices each kernel family's useful work analytically — FLOPs actually
required by the math and the minimum HBM traffic of one launch — and
derives the TPU roofline bound from the chip constants in analysis.py.

On a CPU host the bound is not a prediction of the measured wall time
(the constants are TPU silicon); it is the shape's *position on the
roofline* — arithmetic intensity and which term would dominate on the
target hardware — recorded next to every trajectory point so kernel
regressions can be judged against what the shape can possibly do.
"""
from __future__ import annotations

from repro.roofline.analysis import HBM_BW, PEAK_FLOPS


def kernel_cost(kernel: str, info: dict) -> dict:
    """Useful FLOPs and minimum HBM bytes of one launch of ``kernel``.

    ``info`` is the same call-info dict the dispatch layer sees (n/d for
    the GLM families, k for ELL sparsity, batch/heads/seqs/head_dim for
    attention).  Sparse families are priced at their *useful* work (the
    gather/scatter math), not the one-hot MXU FLOPs the TPU lowering
    spends to avoid irregular access — the roofline is the task's bound,
    not the implementation's.
    """
    f32 = 4
    if kernel == "glm_grad":
        n, d = info["n"], info["d"]
        flops = 4.0 * n * d                      # X@w and X^T@pull
        bytes_ = f32 * (n * d + 2 * n + 2 * d)   # X, y, margins, w, g
    elif kernel == "glm_sgd":
        n, d = info["n"], info["d"]
        flops = 4.0 * n * d                      # same math per epoch
        bytes_ = f32 * (n * d + n + 2 * d)       # model stays resident
    elif kernel == "glm_sparse":
        n, d, k = info["n"], info["d"], info["k"]
        flops = 4.0 * n * k                      # gather-dot + scatter-add
        bytes_ = 2 * f32 * n * k + f32 * n + 2 * f32 * d
    elif kernel == "glm_sgd_sparse":
        n, d, k = info["n"], info["d"], info["k"]
        flops = 4.0 * n * k
        bytes_ = 2 * f32 * n * k + f32 * n + 2 * f32 * d
    elif kernel == "glm_score":
        n, d, k = info["n"], info["d"], info["k"]
        flops = 2.0 * n * k + n                  # gather-dot + link
        bytes_ = 2 * f32 * n * k + f32 * n + f32 * d
    elif kernel == "flash_attn":
        b = info["batch"]
        hq, hkv = info["heads_q"], info["heads_kv"]
        sq, sk, hd = info["seq_q"], info["seq_k"], info["head_dim"]
        flops = 4.0 * b * hq * sq * sk * hd      # QK^T and PV
        bytes_ = f32 * b * (2 * hq * sq * hd + 2 * hkv * sk * hd)
    else:
        raise KeyError(f"no cost model for kernel {kernel!r}")
    return {"flops": flops, "hbm_bytes": float(bytes_)}


def annotate(kernel: str, info: dict, wall_s: float | None = None) -> dict:
    """Roofline terms for one trajectory point.

    Returns flops / hbm_bytes / arithmetic intensity, the TPU
    compute-bound and memory-bound times, which term binds, and — when a
    measured ``wall_s`` is given — the achieved GFLOP/s and the fraction
    of the roofline bound the measurement reached (≈1 only on the target
    silicon; an analytic context field everywhere else).
    """
    cost = kernel_cost(kernel, info)
    compute_s = cost["flops"] / PEAK_FLOPS
    memory_s = cost["hbm_bytes"] / HBM_BW
    bound_s = max(compute_s, memory_s)
    out = {
        **cost,
        "intensity_flops_per_byte": cost["flops"] / cost["hbm_bytes"],
        "tpu_compute_s": compute_s,
        "tpu_memory_s": memory_s,
        "tpu_bound_s": bound_s,
        "bound": "compute" if compute_s >= memory_s else "memory",
    }
    if wall_s:
        out["achieved_gflops"] = cost["flops"] / wall_s / 1e9
        out["roofline_fraction"] = bound_s / wall_s
    return out
