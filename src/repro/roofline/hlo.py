"""Post-SPMD HLO text parser for roofline terms.

Why parsing instead of ``compiled.cost_analysis()``: XLA's cost analysis
counts every while-loop body ONCE (verified empirically — see
EXPERIMENTS.md §Methodology), and all our models scan over layers/chunks,
so its FLOPs are off by the trip counts.  The partitioned HLO text instead
carries explicit ``known_trip_count`` backend configs, per-op output shapes
and collective replica groups, from which we reconstruct:

  * dot FLOPs x loop-trip multipliers  (compute term; per-device shapes)
  * per-op HBM traffic proxy           (memory term; post-fusion top level)
  * collective wire bytes per device   (collective term; ring formulas)

All shapes in the partitioned module are per-device.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
               "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
               "u64": 8, "c64": 8, "token": 0, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+"
                    r"([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"(?:true_computation|false_computation|"
                          r"branch_computations=\{)[^,}]*")
_GROUPS_ITOA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions.

    Some releases return one properties dict, others a list with one dict
    per partition/device (all partitions report identical totals for SPMD
    modules, so the first entry is the per-device view we want).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def first_shape(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    kind: str
    rest: str            # everything after the opening '('


@dataclasses.dataclass
class Computation:
    name: str
    ops: list            # list[Op]
    symbols: dict        # op name -> type_str


def parse_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        hdr = _COMP_HDR_RE.match(stripped)
        if hdr and stripped.endswith("{"):
            cur = Computation(hdr.group(1), [], {})
            comps[cur.name] = cur
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(stripped)
        if m:
            name, type_str, kind, rest = m.groups()
            cur.ops.append(Op(name, type_str, kind, rest))
            cur.symbols[name] = type_str
    return comps


def _entry_name(comps: dict[str, Computation], text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    if m:
        return m.group(1)
    # fallback: computation not called by anyone
    called = set()
    for c in comps.values():
        for op in c.ops:
            for rx in (_CALLS_RE, _BODY_RE, _COND_RE):
                mm = rx.search(op.rest)
                if mm:
                    called.add(mm.group(1))
    for name in comps:
        if name not in called:
            return name
    return next(iter(comps))


def compute_multipliers(comps: dict[str, Computation], entry: str
                        ) -> dict[str, float]:
    """multiplier[c] = expected executions of computation c per step."""
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # topological-ish propagation: iterate until fixpoint (call graphs are
    # DAGs in HLO, a few passes suffice)
    for _ in range(32):
        changed = False
        for cname, comp in comps.items():
            base = mult.get(cname, 0.0)
            if base == 0.0:
                continue
            for op in comp.ops:
                trips = 1.0
                tm = _TRIP_RE.search(op.rest)
                if op.kind == "while":
                    trips = float(tm.group(1)) if tm else 1.0
                    for rx, t in ((_BODY_RE, trips), (_COND_RE, trips + 1)):
                        mm = rx.search(op.rest)
                        if mm:
                            new = base * t
                            if mult[mm.group(1)] < new:
                                mult[mm.group(1)] = new
                                changed = True
                else:
                    mm = _CALLS_RE.search(op.rest)
                    if mm:
                        if mult[mm.group(1)] < base:
                            mult[mm.group(1)] = base
                            changed = True
                    for b in re.finditer(r"(?:true_computation=|"
                                         r"false_computation=)%?([\w.\-]+)",
                                         op.rest):
                        if mult[b.group(1)] < base:
                            mult[b.group(1)] = base
                            changed = True
        if not changed:
            break
    return dict(mult)


def _operand_names(rest: str) -> list[str]:
    # operands are %name tokens before any ')', attributes follow
    args = rest.split(")")[0]
    return re.findall(r"%([\w.\-]+)", args)


def dot_flops(comps, mult) -> tuple[float, dict]:
    """Total dot FLOPs (per device) with loop multipliers; split by input
    dtype (bf16-input dots hit the MXU at full rate, f32 at 1/4)."""
    total = 0.0
    by_dtype = defaultdict(float)
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for op in comp.ops:
            if op.kind not in ("dot", "convolution"):
                continue
            _, out_dims = first_shape(op.type_str)
            out_elems = math.prod(out_dims) if out_dims else 1
            ops_names = _operand_names(op.rest)
            lhs_type = comp.symbols.get(ops_names[0]) if ops_names else None
            contract = 1
            lc = _LHS_CONTRACT_RE.search(op.rest)
            if lhs_type and lc and lc.group(1):
                _, lhs_dims = first_shape(lhs_type)
                for idx in lc.group(1).split(","):
                    i = int(idx)
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
            flops = 2.0 * out_elems * contract * m
            total += flops
            in_dt = (first_shape(lhs_type)[0] if lhs_type else None) or "f32"
            by_dtype[in_dt] += flops
    return total, dict(by_dtype)


def collective_bytes(comps, mult) -> tuple[float, dict]:
    """Effective wire bytes per device (ring formulas), with multipliers.

    all-gather:      (N-1)/N * output bytes
    reduce-scatter:  (N-1)/N * input bytes
    all-reduce:      2 * (N-1)/N * bytes        (RS + AG)
    all-to-all:      (N-1)/N * bytes
    collective-permute: bytes
    """
    total = 0.0
    by_kind = defaultdict(float)
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for op in comp.ops:
            if op.kind not in COLLECTIVES:
                continue
            n = _group_size(op.rest)
            frac = (n - 1) / n if n > 1 else 0.0
            b = shape_bytes(op.type_str)
            if op.kind == "all-reduce":
                wire = 2.0 * frac * b
            elif op.kind == "collective-permute":
                wire = float(b)
            else:
                wire = frac * b
            total += wire * m
            by_kind[op.kind] += wire * m
    return total, dict(by_kind)


def _group_size(rest: str) -> int:
    m = _GROUPS_ITOA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    return 1


_SKIP_MEM = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "iota"}


def memory_bytes(comps, mult, fusion_internal: set[str]) -> float:
    """HBM traffic proxy: sum over top-level ops of (operand + output
    bytes) x multiplier, excluding fusion-internal computations and pure
    bookkeeping ops.  Collectives excluded (counted in their own term).

    In-place ops are modeled physically, not syntactically: XLA aliases the
    big buffer of dynamic-update-slice / scatter (writes only the slice) and
    dynamic-slice / gather read only the slice — counting the full operand
    per loop trip would make every lax.scan output-stacking look quadratic.
    """
    total = 0.0
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0 or cname in fusion_internal:
            continue
        for op in comp.ops:
            if op.kind in _SKIP_MEM or op.kind in COLLECTIVES:
                continue
            if op.kind in ("while", "call", "conditional"):
                continue  # their bodies are counted directly
            total += _op_traffic(comp, op) * m
    return total


def _type_sig(type_str: str) -> str:
    """dtype+dims signature, ignoring layout braces."""
    return ";".join(f"{m.group(1)}[{m.group(2)}]"
                    for m in _SHAPE_RE.finditer(type_str))


def _op_traffic(comp: Computation, op: Op) -> float:
    """Physical HBM bytes of one op: read inputs once + write output once,
    with in-place aliasing: when an operand's type equals the output type
    (dynamic-update-slice / scatter / DUS-rooted fusions), the big buffer is
    aliased — only the *other* operands (the update slice) move, each capped
    at the output size."""
    names = _operand_names(op.rest)
    out_b = shape_bytes(op.type_str)
    out_sig = _type_sig(op.type_str)
    opnd = [(n, comp.symbols.get(n)) for n in names]
    opnd_b = [(n, shape_bytes(t) if t else 0, t) for n, t in opnd]

    if op.kind in ("dynamic-slice", "gather", "slice"):
        return 2.0 * out_b                      # slice read + slice write
    if op.kind in ("dynamic-update-slice", "scatter"):
        small = sum(b for i, (n, b, t) in enumerate(opnd_b) if i != 0)
        return 2.0 * small                      # update read + update write

    aliased = None
    for i, (n, b, t) in enumerate(opnd_b):
        if t and _type_sig(t) == out_sig:
            aliased = i
            break
    if op.kind == "fusion":
        if aliased is not None and len(opnd_b) > 1:
            # DUS-rooted fusion: the aliased buffer stays put; the real
            # traffic is the other operands (the updates), capped at out
            small = sum(min(b, out_b) for i, (n, b, t) in enumerate(opnd_b)
                        if i != aliased)
            return 2.0 * max(small, 1.0)
        # fusions internally dynamic-slice big loop-carried operands: a
        # tiny-output fusion cannot physically stream a full buffer per
        # trip — cap each operand read at 8x the fusion output
        capped = sum(min(b, 8 * out_b) for _, b, _ in opnd_b)
        return out_b + capped
    in_b = sum(b for _, b, _ in opnd_b)
    return out_b + in_b


def fusion_internal_comps(comps) -> set[str]:
    """Computations reachable only via fusion ``calls=`` / reducers."""
    internal = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "fusion":
                m = _CALLS_RE.search(op.rest)
                if m:
                    internal.add(m.group(1))
            m = re.search(r"to_apply=%?([\w.\-]+)", op.rest)
            if m:
                internal.add(m.group(1))
            m = re.search(r"comparator=%?([\w.\-]+)", op.rest)
            if m:
                internal.add(m.group(1))
    return internal


@dataclasses.dataclass
class HLOAnalysis:
    dot_flops: float
    dot_flops_by_dtype: dict
    collective_bytes: float
    collective_by_kind: dict
    memory_bytes: float
    n_while: int
    max_trip: int


def analyze(text: str) -> HLOAnalysis:
    comps = parse_computations(text)
    entry = _entry_name(comps, text)
    mult = compute_multipliers(comps, entry)
    flops, by_dt = dot_flops(comps, mult)
    coll, by_kind = collective_bytes(comps, mult)
    internal = fusion_internal_comps(comps)
    mem = memory_bytes(comps, mult, internal)
    n_while = sum(1 for c in comps.values() for op in c.ops
                  if op.kind == "while")
    trips = [int(m.group(1)) for c in comps.values() for op in c.ops
             if op.kind == "while"
             for m in [_TRIP_RE.search(op.rest)] if m]
    return HLOAnalysis(flops, by_dt, coll, by_kind, mem, n_while,
                       max(trips) if trips else 0)
