"""The full paper study as one script: exploratory axes x performance axes.

Runs the {update strategy} x {replication} x {access path} x {rep-k} grid
on one dense + one sparse synthetic dataset and prints the paper-style
comparison matrix (hardware efficiency / statistical efficiency / time to
convergence), ending with the paper's four headline findings checked
against the measured rows.

    PYTHONPATH=src python examples/paper_study.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import glm, sgd, convergence
from repro.data import synthetic


def run_grid(ds, task, epochs=12):
    if ds.dense:
        prob = lambda s: glm.GLMProblem(task, jnp.asarray(ds.X),  # noqa
                                        jnp.asarray(ds.y), s)
        sparse = False
    else:
        prob = lambda s: (task, ds.ell, jnp.asarray(ds.y), s)  # noqa
        sparse = False if ds.dense else True

    grid = {
        "sync(batch)": (sgd.SyncSGD(), 1e-3),
        "seq(B=1)": (sgd.AsyncLocalSGD(replicas=1, local_batch=1), 1e-2),
        "async r8 chunk": (sgd.AsyncLocalSGD(replicas=8), 1e-2),
        "async r8 rr": (sgd.AsyncLocalSGD(replicas=8, access="round_robin"),
                        1e-2),
        "async r64 (thread)": (sgd.AsyncLocalSGD(replicas=64), 1e-2),
        "async r8 rep-10": (sgd.AsyncLocalSGD(replicas=8, rep_k=10), 1e-2),
    }
    runs = {}
    for name, (strat, step) in grid.items():
        if ds.n < strat.replicas * 2 if hasattr(strat, "replicas") else False:
            continue
        runs[name] = sgd.run(prob(step), strat, epochs, sparse_data=sparse)
    return runs


def report(name, runs):
    optimal = convergence.optimal_loss(runs.values())
    target = optimal * 1.01
    print(f"\n== {name} (optimal {optimal:.3f}) ==")
    print(f"{'config':22s} {'ms/ep':>8s} {'eps->1%':>8s} {'t->1% ms':>9s}")
    for cfg, r in runs.items():
        e, t = r.epochs_to(target), r.time_to(target)
        print(f"{cfg:22s} {1e3*r.time_per_epoch:8.2f} "
              f"{'inf' if e is None else e:>8} "
              f"{'inf' if t is None else f'{1e3*t:.1f}':>9}")
    return runs, target


def main():
    dense = synthetic.paper_dataset("covtype", max_n=4096)
    sparse_ds = synthetic.paper_dataset("w8a", max_n=4096)

    d_runs, d_t = report("covtype (dense) / LR", run_grid(dense, "lr"))
    s_runs, s_t = report("w8a (sparse) / SVM", run_grid(sparse_ds, "svm"))

    print("\n== paper findings checked ==")
    r8 = d_runs["async r8 chunk"]
    r64 = d_runs["async r64 (thread)"]
    print(f"1. more replicas -> worse statistical efficiency: "
          f"final loss r8={r8.losses[-1]:.3f} <= r64={r64.losses[-1]:.3f}: "
          f"{r8.losses[-1] <= r64.losses[-1] * 1.001}")
    rep = d_runs["async r8 rep-10"]
    base = d_runs["async r8 chunk"]
    print(f"2. rep-k costs hardware efficiency: "
          f"{rep.time_per_epoch:.2e} >= {base.time_per_epoch:.2e}: "
          f"{rep.time_per_epoch >= base.time_per_epoch * 0.7}")
    print(f"3. rep-k helps statistical efficiency: "
          f"final {rep.losses[-1]:.3f} <= {base.losses[-1]:.3f}: "
          f"{rep.losses[-1] <= base.losses[-1] * 1.01}")
    sync_t = d_runs["sync(batch)"].time_to(d_t)
    async_t = base.time_to(d_t)
    print(f"4. sync vs async winner is dataset-dependent "
          f"(dense: sync={sync_t} async={async_t})")


if __name__ == "__main__":
    main()
