"""Serve a small model with batched requests (continuous-batching engine).

    PYTHONPATH=src python examples/serve_lm.py --requests 6 --slots 2
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.nn import transformer
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.reduced(configs.get(args.arch))
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, slots=args.slots, max_len=128,
                         temperature=args.temperature)

    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, size=rng.integers(2, 8)),
                    max_new=args.max_new)
            for i in range(args.requests)]

    t0 = time.time()
    done = engine.run(reqs, max_ticks=2000)
    dt = time.time() - t0
    total = sum(len(r.out) for r in done)
    print(f"{len(done)}/{len(reqs)} requests served on {args.slots} slots; "
          f"{total} tokens in {dt:.1f}s ({total/dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.rid}: prompt {list(r.prompt)} -> {r.out[:10]}...")
    assert len(done) == len(reqs)


if __name__ == "__main__":
    main()
