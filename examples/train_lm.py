"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the h2o-danube family config scaled to ~100M parameters (the paper's
update-strategy axis applies unchanged: pass --update async to train with
per-replica models + periodic merges instead of synchronous SGD).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 200 --update async
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.launch.train import make_batch_fn
from repro.nn import transformer
from repro.optim.sgd import sgd_momentum, apply_updates
from repro.train import fault


def lm_100m():
    """~100M-parameter danube-family config (24L x 512 with 32k vocab)."""
    base = configs.get("h2o-danube-1.8b")
    return configs.reduced(
        base, n_layers=8, d_model=512, n_heads=8, n_kv=4, d_ff=1536,
        vocab=32_000, window=256, head_dim=64,
        attn_chunk=128, loss_chunk=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--update", default="sync", choices=["sync", "async"])
    ap.add_argument("--merge-every", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/train_lm_ckpt")
    args = ap.parse_args()

    cfg = lm_100m()
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M parameters, "
          f"update={args.update}")

    opt = sgd_momentum(args.lr, 0.9)
    batches = make_batch_fn(cfg, args.batch, args.seq, fixed=True)

    def loss_of(p, b):
        return transformer.loss_fn(p, cfg, b)

    if args.update == "sync":
        @jax.jit
        def step(state, batch):
            p, o = state
            loss, g = jax.value_and_grad(loss_of)(p, batch)
            u, o = opt.update(g, o, p)
            return (apply_updates(p, u), o), {"loss": loss}

        state = (params, opt.init(params))
    else:
        R = 2

        def one(p, o, b):
            loss, g = jax.value_and_grad(loss_of)(p, b)
            u, o = opt.update(g, o, p)
            return apply_updates(p, u), o, loss

        me = args.merge_every

        @jax.jit
        def step(state, batch):
            p, o, t = state
            bs = jax.tree.map(
                lambda x: x.reshape(R, x.shape[0] // R, *x.shape[1:]), batch)
            p, o, loss = jax.vmap(one)(p, o, bs)
            p = jax.lax.cond(
                (t + 1) % me == 0,
                lambda q: jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        jnp.mean(x.astype(jnp.float32), 0, keepdims=True
                                 ).astype(x.dtype), x.shape), q),
                lambda q: q, p)
            return (p, o, t + 1), {"loss": jnp.mean(loss)}

        stack = lambda t: jax.tree.map(  # noqa: E731
            lambda x: jnp.broadcast_to(x[None], (R, *x.shape)), t)
        state = (stack(params), jax.vmap(opt.init)(stack(params)),
                 jnp.zeros((), jnp.int32))

    ckpt = CheckpointManager(args.ckpt, keep=2, every=100)
    loop = fault.ResilientLoop(step, ckpt, state, resume=False)
    t0 = time.time()
    _, history = loop.run(batches, args.steps)
    losses = [float(m["loss"]) for k, _, m in history if k == "step"]
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"steps={len(losses)} loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({dt:.0f}s, {toks/dt:.0f} tok/s)")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
