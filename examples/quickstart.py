"""Quickstart: the paper's study in 60 seconds.

Trains logistic regression on a synthetic covtype-like dataset with the
three SGD strategies the paper compares — sequential, synchronous parallel,
and asynchronous replica-merge (Hogwild-family) — and prints the three
performance axes for each: hardware efficiency (time/epoch), statistical
efficiency (epochs to 1% error) and time to convergence.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import glm, sgd, convergence
from repro.data import synthetic


def main():
    ds = synthetic.paper_dataset("covtype", max_n=4096)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)

    strategies = {
        "sequential (B=1)": (sgd.AsyncLocalSGD(replicas=1, local_batch=1),
                             1e-2),
        "synchronous (batch)": (sgd.SyncSGD(), 1e-3),
        "async 8 replicas": (sgd.AsyncLocalSGD(replicas=8, local_batch=1),
                             1e-2),
        "async 8 replicas rep-5": (sgd.AsyncLocalSGD(replicas=8,
                                                     local_batch=1, rep_k=5),
                                   1e-2),
    }

    runs = {}
    for name, (strat, step) in strategies.items():
        prob = glm.GLMProblem("lr", X, y, step)
        runs[name] = sgd.run(prob, strat, epochs=15)

    optimal = convergence.optimal_loss(runs.values())
    target = optimal * 1.01
    print(f"optimal loss seen: {optimal:.4f}  (1% target {target:.4f})\n")
    print(f"{'strategy':26s} {'ms/epoch':>9s} {'epochs→1%':>10s} "
          f"{'time→1% (s)':>12s}  final loss")
    for name, r in runs.items():
        e = r.epochs_to(target)
        t = r.time_to(target)
        print(f"{name:26s} {1e3*r.time_per_epoch:9.2f} "
              f"{'∞' if e is None else e:>10} "
              f"{'∞' if t is None else f'{t:.3f}':>12}  {r.losses[-1]:.4f}")

    print("\nThe paper's trade-off is visible: async replicas cut per-epoch "
          "cost per worker\nbut need more epochs; rep-k replication buys "
          "statistical efficiency back.")


if __name__ == "__main__":
    main()
