"""Paper Fig 22/23: best synchronous vs best asynchronous, loss-vs-time.

Same hyper-parameters and initialization; the paper's conclusion — the
winner is task/dataset-dependent (BGD vs SGD in disguise) — is reproduced
as a per-(dataset, task) verdict table."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import sgd


def run(profile: str = "ci"):
    p = common.PROFILES[profile]
    rows = []
    for name in common.profile_datasets(profile):
        dspec = common.dataset_spec(name, profile)
        for task in common.TASKS:
            _, sync_res, _ = common.tune(
                dspec, task, sgd.SyncSGD(), p["epochs"])
            _, async_res, _ = common.tune(
                dspec, task, sgd.AsyncLocalSGD(replicas=8, local_batch=1),
                p["epochs"], steps=(1e-2, 1e-1))
            best = min(float(np.nanmin(sync_res.losses)),
                       float(np.nanmin(async_res.losses)))
            target = best * 1.01 if best > 0 else best * 0.99
            ts = sync_res.time_to(target)
            ta = async_res.time_to(target)
            winner = ("sync" if (ta is None or (ts is not None and ts <= ta))
                      else "async")
            rows.append(dict(
                dataset=name, task=task,
                sync_time_to_1pct_s=ts, async_time_to_1pct_s=ta,
                winner=winner))
    common.write_csv(rows, "fig22_sync_vs_async.csv")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
