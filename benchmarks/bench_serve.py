"""Scoring-service benchmark trajectory producer -> ``BENCH_serve.json``.

One trajectory point per (batch size, sparsity) cell of the GLM scoring
service (``repro.serve.glm``): a synthetic padded-ELL request stream is
admitted through the engine's bounded FIFO and scored in padded
micro-batches by the fused ``glm_score`` kernel; the point records the
request-latency quantiles (p50/p99, admission -> response), the
sustained requests/s of the drain, the conformance verdict of every
dispatchable Pallas flavor of ``glm_score`` against its oracle at that
shape, and the analytic roofline annotation of one scoring launch.

Determinism contract (same as ``BENCH_kernels.json``): measured
latencies are cached in ``bench_results/serve_cache`` keyed by the
entry identity (shape, engine config, backend, host, device kind) — a
warm re-run reads the cache and writes a byte-identical
``BENCH_serve.json``, which CI asserts.  The regression gate
(``claims.check_bench_serve``) compares each point's p50 against the
*committed* trajectory entry with the same label, host, and device
kind — cross-host latencies never gate — and its baseline lookups stay
out of the snapshot so the file remains a pure function of the cache.

Standalone:  PYTHONPATH=src python -m benchmarks.bench_serve [ci|paper]
[--consumers N] (exits non-zero on a conformance or regression
violation).  ``--consumers N`` drives the stream with N dedicated
consumer threads flushing while the main thread produces; those points
carry a ``/cN`` label suffix so they never collide with (or gate
against) the committed single-consumer trajectory.

``--monitor`` attaches a :class:`repro.obs.monitor.HealthMonitor` to a
**shadow drive** of every cell: the health windows, SLO evaluations,
and sidecar output come from a separate re-drive of the request stream,
never from the measured payloads — the cached, committed
``BENCH_serve.json`` stays byte-identical under monitoring (CI asserts
the cmp).  ``--fault-stall S`` makes the shadow engines stall every
flush by S seconds (``GLMScoreEngine(fault_stall_s=...)``) on a
truncated stream — the injected latency spike the ``monitor-smoke``
job turns into a ``latency_p99`` breach.
"""
from __future__ import annotations

import hashlib
import platform
import statistics
import threading
import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.data import synthetic
from repro.kernels import common as kcommon
from repro.kernels import tune
from repro.kernels.glm_score import glm_score
from repro.kernels.glm_score.ref import glm_score_ref
from repro.obs import metrics
from repro.obs.monitor import DEFAULT_SERVE_SLOS, HealthMonitor
from repro.roofline import kernels as roofline
from repro.serve.glm import GLMScoreEngine, ScoreRequest
from repro.study.runner import TrialCache
from repro.study.spec import canonical_json
from repro.study.store import ServeBenchStore
from repro.utils.timing import Timer

#: bump to invalidate every cached latency (measurement protocol changes)
TIMING_SCHEMA = 1

TASK = "lr"

#: per-profile service shape: request count, model width, and the
#: (batch size x ELL sparsity) grid the trajectory sweeps
PROFILES = {
    "ci": dict(n_requests=192, d=512, batches=(8, 32), ks=(4, 16)),
    "paper": dict(n_requests=2048, d=4096, batches=(32, 128), ks=(8, 32)),
}


def _digest(obj) -> str:
    return hashlib.sha256(canonical_json(obj).encode()).hexdigest()[:16]


def _requests(n: int, d: int, k: int):
    """The benchmark request stream + its ELL batch (for conformance)."""
    ds = synthetic.make_sparse(f"bench-serve-{d}-{k}", n, d, k * 0.6, k,
                               seed=0)
    vals = np.asarray(ds.ell.values, np.float32)
    idx = np.asarray(ds.ell.indices, np.int32)
    reqs = [ScoreRequest(i, vals[i], idx[i]) for i in range(n)]
    return reqs, jnp.asarray(vals), jnp.asarray(idx)


def _conformance(w, vals, idx, info) -> tuple[bool | None, list[str]]:
    """Every dispatchable non-reference ``glm_score`` flavor vs the
    oracle at this shape (``None`` when nothing could be checked)."""
    ref = np.asarray(glm_score_ref(TASK, w, vals, idx), np.float32)
    checks = {}
    for b in kcommon.available_backends("glm_score", info=info):
        if b == kcommon.REFERENCE:
            continue
        out = np.asarray(glm_score(TASK, w, vals, idx, backend=b),
                         np.float32)
        checks[b] = bool(np.allclose(out, ref, rtol=1e-3, atol=2e-3))
    if not checks:
        return None, []
    return all(checks.values()), sorted(checks)


def _drive(engine: GLMScoreEngine, reqs) -> dict:
    """Admit + drain the whole stream; returns latency/throughput stats.

    Producers saturate the bounded FIFO (``submit`` blocks on a full
    queue while the same loop drains), so the measured latencies include
    real queueing, not just the launch.
    """
    responses = []
    with Timer() as t:
        pending = list(reqs)
        while pending or len(engine):
            while pending and engine.try_admit(pending[0]):
                pending.pop(0)
            batch = engine.flush()
            if not batch and not pending:
                break
            responses.extend(batch)
    assert len(responses) == len(reqs), (len(responses), len(reqs))
    lat = sorted(r.latency_s for r in responses)
    return {
        "p50_s": statistics.median(lat),
        "p99_s": lat[min(len(lat) - 1, int(0.99 * len(lat)))],
        "rps": len(lat) / max(t.elapsed, 1e-9),
    }


def _drive_threaded(engine: GLMScoreEngine, reqs, consumers: int) -> dict:
    """``_drive`` with N dedicated consumer threads flushing while the
    main thread produces — the deployment shape where scoring capacity
    is scaled independently of admission.  Same stats contract."""
    responses: list = []
    resp_lock = threading.Lock()
    produced = threading.Event()

    def consume():
        while True:
            batch = engine.flush()
            if batch:
                with resp_lock:
                    responses.extend(batch)
            elif produced.is_set() and not len(engine):
                return
            else:
                time.sleep(1e-5)

    threads = [threading.Thread(target=consume) for _ in range(consumers)]
    with Timer() as t:
        for th in threads:
            th.start()
        try:
            for r in reqs:
                engine.submit(r)
        finally:
            produced.set()
            for th in threads:
                th.join()
    assert len(responses) == len(reqs), (len(responses), len(reqs))
    lat = sorted(r.latency_s for r in responses)
    return {
        "p50_s": statistics.median(lat),
        "p99_s": lat[min(len(lat) - 1, int(0.99 * len(lat)))],
        "rps": len(lat) / max(t.elapsed, 1e-9),
    }


def _shadow_drive(mon: HealthMonitor, w, reqs, k: int, engine_cfg: dict, *,
                  fault_stall_s: float) -> None:
    """Health-only re-drive of one cell: a fresh engine is warmed (jit
    compile stays out of the windows), then monitored and driven; the
    window closes at the cell boundary.  Nothing here touches the
    benchmark's measured payloads or the trajectory store.  A nonzero
    ``fault_stall_s`` truncates the stream to two micro-batches so the
    injected stall costs ~2 flushes, not the whole stream."""
    engine = GLMScoreEngine(TASK, w, ell_width=k, **engine_cfg)
    _drive(engine, reqs)                        # warm the scoring launch
    if fault_stall_s:
        reqs = reqs[:2 * engine_cfg["max_batch"]]
    engine = GLMScoreEngine(TASK, w, ell_width=k,
                            fault_stall_s=fault_stall_s, **engine_cfg)
    mon.attach_engine(engine)
    _drive(engine, reqs)
    mon.roll()


def _baseline_p50(committed: dict | None, label: str, host: str,
                  device_kind: str) -> float | None:
    """The committed trajectory's comparable point (same host + device)."""
    entry = (committed or {}).get("entries", {}).get(label)
    if (entry and entry.get("host") == host
            and entry.get("device_kind") == device_kind):
        return entry.get("p50_s")
    return None


def run(profile: str = "ci", *, out_json: str = "BENCH_serve.json",
        consumers: int = 1, monitor: bool = False,
        fault_stall_s: float = 0.0):
    if consumers < 1:
        raise ValueError(f"consumers must be >= 1: {consumers}")
    if fault_stall_s and not monitor:
        raise ValueError("fault_stall_s only affects monitored shadow "
                         "drives; pass monitor=True")
    mon = HealthMonitor(DEFAULT_SERVE_SLOS) if monitor else None
    try:
        committed = ServeBenchStore.load(out_json)
    except (FileNotFoundError, ValueError):
        committed = None
    store = ServeBenchStore(
        out_json, jsonl_path=common.RESULTS_DIR / "serve_runs.jsonl")
    timing_cache = TrialCache(common.RESULTS_DIR / "serve_cache")
    host = platform.node()
    device_kind = tune.device_kind()

    cfg = PROFILES[profile]
    n_req, d = cfg["n_requests"], cfg["d"]
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.normal(0, 0.1, d), jnp.float32)

    rows = []
    for k in cfg["ks"]:
        reqs, vals, idx = _requests(n_req, d, k)
        for batch in cfg["batches"]:
            info = {"dtype": "float32", "sparse": True, "n": batch,
                    "d": d, "k": k}
            backend = kcommon.resolve_backend("glm_score", info=info)
            pallas_match, checked = _conformance(w, vals, idx, info)

            engine_cfg = dict(max_batch=batch, queue_depth=2 * batch,
                              flush_deadline_s=0.0)
            suffix = f"/c{consumers}" if consumers > 1 else ""
            label = f"serve/{TASK}/d{d}-k{k}/batch{batch}{suffix}"
            key = _digest({"timing_schema": TIMING_SCHEMA, "label": label,
                           "profile": profile, "backend": backend,
                           "engine": engine_cfg, "consumers": consumers,
                           "host": host, "device_kind": device_kind})
            payload = timing_cache.peek(key)
            if payload is None:
                engine = GLMScoreEngine(TASK, w, ell_width=k, **engine_cfg)
                _drive(engine, reqs)        # warmup (jit compile)
                engine = GLMScoreEngine(TASK, w, ell_width=k, **engine_cfg)
                t0 = time.perf_counter()
                payload = (_drive(engine, reqs) if consumers == 1 else
                           _drive_threaded(engine, reqs, consumers))
                timing_cache.put(key, payload)
                cached = False
                store.record_event("serve_timing", label=label,
                                   wall_s=time.perf_counter() - t0,
                                   **payload)
            else:
                cached = True

            entry = {
                "kernel": "glm_score",
                "task": TASK,
                "n_requests": n_req,
                "d": d,
                "k": k,
                "batch": batch,
                "engine": engine_cfg,
                "consumers": consumers,
                "backend": backend,
                "p50_s": payload["p50_s"],
                "p99_s": payload["p99_s"],
                "rps": payload["rps"],
                "pallas_match": pallas_match,
                "checked_backends": checked,
                "roofline": roofline.annotate("glm_score", info),
                "host": host,
                "device_kind": device_kind,
            }
            store.record_entry(label, entry, cached=cached)
            rows.append({
                "label": label, **entry,
                "baseline_p50_s": _baseline_p50(committed, label, host,
                                                device_kind),
            })
            if mon is not None:
                _shadow_drive(mon, w, reqs, k, engine_cfg,
                              fault_stall_s=fault_stall_s)
    out = store.write()
    print(f"wrote {out} ({len(rows)} trajectory points)")
    if mon is not None:
        print("\nhealth (shadow drives, sidecar-only):")
        print(mon.table())
        s = mon.summary()
        print(f"windows={s['windows']} breaches={s['total_breaches']} "
              f"{s['breaches'] or ''}")
        metrics.flush(0)
    return rows


if __name__ == "__main__":
    import argparse
    import sys

    from repro.study import claims

    ap = argparse.ArgumentParser()
    ap.add_argument("profile", nargs="?", default="ci",
                    choices=list(PROFILES))
    ap.add_argument("--consumers", type=int, default=1,
                    help="dedicated consumer threads flushing the engine "
                         "while the main thread produces (1 = the classic "
                         "single-loop driver; >1 points get a /cN label)")
    ap.add_argument("--monitor", action="store_true",
                    help="attach a HealthMonitor to shadow drives of every "
                         "cell (sidecar-only; BENCH_serve.json unchanged)")
    ap.add_argument("--fault-stall", type=float, default=0.0,
                    metavar="S", help="monitored shadow engines stall every "
                                      "flush by S seconds (latency-spike "
                                      "fault injection)")
    ap.add_argument("--out-json", default="BENCH_serve.json",
                    help="trajectory output path (CI fault runs point this "
                         "at scratch)")
    args = ap.parse_args()
    rows = run(args.profile, out_json=args.out_json,
               consumers=args.consumers, monitor=args.monitor,
               fault_stall_s=args.fault_stall)
    for r in rows:
        print(f"  {r['label']:36s} p50={1e6 * r['p50_s']:9.1f}us "
              f"p99={1e6 * r['p99_s']:9.1f}us rps={r['rps']:9.0f} "
              f"match={r['pallas_match']}")
    bad = claims.check_bench_serve(rows)
    if bad:
        print("VIOLATIONS:")
        for v in bad:
            print("  - " + v)
        sys.exit(1)
    print("serve conformance + regression gate clean")
