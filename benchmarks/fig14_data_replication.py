"""Paper Fig 14/15: k-wise data replication (rep-0/2/5/10).

Asserts the paper's trade: hardware efficiency drops ~linearly in k (each
replica processes k extra examples) while statistical efficiency improves."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import sgd

KS = (0, 2, 5, 10)


def run(profile: str = "ci"):
    p = common.PROFILES[profile]
    rows = []
    for name in common.profile_datasets(profile)[:2]:
        dspec = common.dataset_spec(name, profile)
        for task in ("lr",):
            per = {}
            for k in KS:
                strat = sgd.AsyncLocalSGD(replicas=8, local_batch=1, rep_k=k)
                step, res, target = common.tune(
                    dspec, task, strat, p["epochs"], steps=(1e-2, 1e-1))
                per[k] = res
            best = min(float(np.nanmin(r.losses)) for r in per.values())
            target = best * 1.01 if best > 0 else best * 0.99
            for k, res in per.items():
                rows.append(dict(
                    dataset=name, task=task, rep_k=k,
                    t_epoch_ms=1e3 * res.time_per_epoch,
                    epochs_to_1pct=res.epochs_to(target),
                    final_loss=float(res.losses[-1]),
                ))
    common.write_csv(rows, "fig14_data_replication.csv")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
