"""Paper Table 7/8: asynchronous SGD — time to convergence, time/iter,
#iterations for seq / parallel(8 replicas) / massively-parallel(64 replicas,
the GPU-analogue) configurations.

The paper's claim reproduced here: more replicas buy hardware efficiency per
pass but cost statistical efficiency; the massively-replicated configuration
needs rep-k data replication to converge well (Table 6/7 discussion).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import sgd


CONFIGS = {
    "seq": sgd.AsyncLocalSGD(replicas=1, local_batch=1),
    "cpu-par": sgd.AsyncLocalSGD(replicas=8, local_batch=1),
    "gpu-norep": sgd.AsyncLocalSGD(replicas=64, local_batch=1),
    "gpu-rep10": sgd.AsyncLocalSGD(replicas=64, local_batch=1, rep_k=10),
}


def run(profile: str = "ci"):
    p = common.PROFILES[profile]
    rows = []
    for name in common.profile_datasets(profile):
        dspec = common.dataset_spec(name, profile)
        n = dspec.profile().n
        for task in common.TASKS:
            per_cfg = {}
            for label, strat in CONFIGS.items():
                if n < strat.replicas * 2:
                    continue
                step, res, target = common.tune(
                    dspec, task, strat, p["epochs"])
                per_cfg[label] = (res, target, step)
            # common target: within 1% of the best loss seen anywhere
            best = min(float(np.nanmin(r.losses))
                       for r, _, _ in per_cfg.values())
            target = best * 1.01 if best > 0 else best * 0.99
            for label, (res, _, step) in per_cfg.items():
                rows.append(dict(
                    dataset=name, task=task, config=label,
                    t_iter_ms=1e3 * res.time_per_epoch,
                    iters_to_1pct=res.epochs_to(target),
                    time_to_1pct_s=res.time_to(target),
                    final_loss=float(res.losses[-1]),
                    best_step=step,
                ))
    common.write_csv(rows, "table7_async.csv")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
