"""Shared benchmark utilities — thin glue over the study subsystem.

The container is a single-core CPU host, so the paper's CPU/GPU hardware
axis is reproduced as *execution paths* of the same math (see DESIGN.md §2):

    seq        sequential incremental SGD (paper: cpu-seq)
    sync       synchronous batch SGD, fused XLA gradient (paper: parallel
               sync; on TPU this is the MXU path)
    sync-comp  synchronous batch SGD via the primitive-composition path with
               materialization barriers (paper: ViennaCL/TensorFlow/BIDMach)
    async-rN   async-local SGD with N model replicas (paper: Hogwild; N maps
               the kernel/block/thread replication axis)

Datasets default to synthetic stand-ins matching Table 3 statistics,
scaled by --profile (ci: tiny / paper: larger) for single-core
wall-clock sanity.  ``--real`` (benchmarks.run) flips the module-level
``SOURCE`` to "real": every sweep then loads the paper's measured
datasets through ``repro.data.ingest`` — bundled miniature fixtures
offline, cached full downloads when ``REPRO_ALLOW_DOWNLOAD=1`` fetched
them — and every trial-cache key embeds the ingested content hash.

Sweep execution goes through ``repro.study``: every (dataset, task,
strategy, step) cell is a ``TrialSpec`` executed by the module-level
``RUNNER`` — step grids run vmap-stacked, results land in the on-disk
trial cache (interrupted sweeps resume; repeated sweeps are pure cache
reads), and, when the driver attaches a ``StudyStore``, every trial is
recorded into ``BENCH_study.json``.  With ``--workers N``
(benchmarks.run) the driver also attaches a ``repro.sweep`` executor
to the shared runner: cache-miss dispatches spanning multiple stack
groups (the advisor's batched candidate space) execute across N
worker subprocesses whose private caches merge back into
``bench_results/study_cache`` — same bytes, more hosts busy — while
single-grid dispatches stay in-process.
"""
from __future__ import annotations

import csv
from pathlib import Path

from repro.study import runner as runner_mod
from repro.study import spec as spec_mod
from repro.study import tuner as tuner_mod

RESULTS_DIR = Path(__file__).resolve().parent.parent / "bench_results"

# profile -> (dataset max_n, epochs, synthetic + real dataset name tuples)
PROFILES = {
    "ci": dict(max_n=2048, epochs=12,
               datasets=("covtype", "w8a", "real-sim"),
               real_datasets=("covtype", "w8a", "real-sim")),
    "paper": dict(max_n=16384, epochs=30,
                  datasets=("covtype", "w8a", "real-sim", "rcv1", "news"),
                  real_datasets=("covtype", "w8a", "real-sim", "news",
                                 "skin")),
}

TASKS = ("lr", "svm")

#: dataset source for every sweep: "synthetic" | "real" (set by --real)
SOURCE = "synthetic"

#: shared trial runner: one dataset memo + trial cache for the whole sweep;
#: the driver (benchmarks.run) attaches a StudyStore to record every trial
RUNNER = runner_mod.Runner(cache_dir=RESULTS_DIR / "study_cache")


def set_source(source: str) -> None:
    """Switch every benchmark module between synthetic and real data."""
    global SOURCE
    assert source in ("synthetic", "real"), source
    SOURCE = source


def profile_datasets(profile: str) -> tuple[str, ...]:
    """The dataset names a sweep iterates, source-aware.

    The paper profile's real list swaps rcv1 (no bundled fixture) for
    skin — the five datasets the paper actually measures.
    """
    p = PROFILES[profile]
    return p["real_datasets"] if SOURCE == "real" else p["datasets"]


def dataset_spec(name: str, profile: str) -> spec_mod.DatasetSpec:
    return spec_mod.DatasetSpec(name, max_n=PROFILES[profile]["max_n"],
                                source=SOURCE)


def load(name: str, profile: str):
    """The materialized dataset (memoized in the shared runner)."""
    return RUNNER.dataset(dataset_spec(name, profile))


def tune(dspec: spec_mod.DatasetSpec, task: str, strategy, epochs: int,
         steps=(1e-3, 1e-2, 1e-1)):
    """Mini grid search (paper §6.1) through the study tuner.

    Returns ``(best_step, best_result, target)`` like the old inline
    helper, but cached, stacked, and store-recorded.
    """
    base = spec_mod.TrialSpec(dataset=dspec, task=task, strategy=strategy,
                              step=steps[0], epochs=epochs)
    t = tuner_mod.tune_step(RUNNER, base, steps=steps)
    return t.best_step, t.best_result, t.target


def write_csv(rows: list[dict], name: str):
    RESULTS_DIR.mkdir(exist_ok=True)
    if not rows:
        return
    path = RESULTS_DIR / name
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    print(f"wrote {path} ({len(rows)} rows)")


def fmt(x):
    if x is None:
        return "inf"
    if isinstance(x, float):
        return f"{x:.4g}"
    return str(x)
