"""Shared benchmark utilities.

The container is a single-core CPU host, so the paper's CPU/GPU hardware
axis is reproduced as *execution paths* of the same math (see DESIGN.md §2):

    seq        sequential incremental SGD (paper: cpu-seq)
    sync       synchronous batch SGD, fused XLA gradient (paper: parallel
               sync; on TPU this is the MXU path)
    sync-comp  synchronous batch SGD via the primitive-composition path with
               materialization barriers (paper: ViennaCL/TensorFlow/BIDMach)
    async-rN   async-local SGD with N model replicas (paper: Hogwild; N maps
               the kernel/block/thread replication axis)

Datasets are synthetic stand-ins matching Table 3 statistics, scaled by
--profile (ci: tiny / paper: larger) for single-core wall-clock sanity.
"""
from __future__ import annotations

import csv
import dataclasses
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import glm, sgd, convergence
from repro.data import synthetic

RESULTS_DIR = Path(__file__).resolve().parent.parent / "bench_results"

# profile -> (dataset max_n, epochs, datasets)
PROFILES = {
    "ci": dict(max_n=2048, epochs=12,
               datasets=("covtype", "w8a", "real-sim")),
    "paper": dict(max_n=16384, epochs=30,
                  datasets=("covtype", "w8a", "real-sim", "rcv1", "news")),
}

TASKS = ("lr", "svm")


def load(name: str, profile: str):
    p = PROFILES[profile]
    scale = 1.0  # max_n caps the size; keep sparsity profile
    return synthetic.paper_dataset(name, scale=scale, max_n=p["max_n"])


def problem_for(ds, task: str, step: float):
    if ds.dense:
        return glm.GLMProblem(task, jnp.asarray(ds.X), jnp.asarray(ds.y),
                              step), False
    return (task, ds.ell, jnp.asarray(ds.y), step), True


def run_config(ds, task, strategy, step, epochs):
    prob, sp = problem_for(ds, task, step)
    return sgd.run(prob, strategy, epochs, sparse_data=sp)


def best_over_steps(ds, task, strategy, epochs, steps=(1e-3, 1e-2, 1e-1)):
    """Mini grid search (paper §6.1): best time-to-lowest-seen loss."""
    runs = {s: run_config(ds, task, strategy, s, epochs) for s in steps}
    opt = convergence.optimal_loss(runs.values())
    target = opt * 1.01 if opt > 0 else opt * 0.99
    best, best_key = None, None
    for s, r in runs.items():
        t = r.time_to(target)
        key = (0, t) if t is not None else (1, float(r.losses[-1]))
        if best_key is None or key < best_key:
            best, best_key = (s, r), key
    return best[0], best[1], target


def write_csv(rows: list[dict], name: str):
    RESULTS_DIR.mkdir(exist_ok=True)
    if not rows:
        return
    path = RESULTS_DIR / name
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    print(f"wrote {path} ({len(rows)} rows)")


def fmt(x):
    if x is None:
        return "inf"
    if isinstance(x, float):
        return f"{x:.4g}"
    return str(x)
