"""Live-training benchmark trajectory producer -> ``BENCH_live.json``.

Two cell families per profile, both over :mod:`repro.live`:

* **convergence** — the online replica-merge learner over a seeded
  synthetic stream: holdout-loss curve at fixed step checkpoints, wall
  time, steps/s, merges.  Swept over (replicas, compressed-merge) so
  the trajectory shows what the int8 error-feedback channel and the
  replica count cost/buy in the continual setting (the online analogue
  of the study engine's Table-7 cells).
* **serve** — latency under training: a scoring thread admits+flushes a
  request stream against the engine while the learner trains and the
  publisher hot-swaps snapshots concurrently.  Records request-latency
  quantiles, throughput, publishes, the measured staleness vs the
  publisher's guaranteed bound, and whether served versions stayed
  non-decreasing — the consistency half of the cell is gated, not just
  the speed.

Determinism contract (same as ``BENCH_serve.json``): the full measured
payload of every cell — losses, wall times, latencies, staleness — is
cached in ``bench_results/live_cache`` keyed by the cell identity
(profile, config, host, device kind).  A warm re-run is a pure cache
read and writes a byte-identical ``BENCH_live.json``, which CI asserts
(the ``live-smoke`` job).  The regression gate
(``claims.check_bench_live``) compares against the *committed*
trajectory only for the same host + device kind, and its baseline
lookups stay out of the snapshot.

Standalone:  PYTHONPATH=src python -m benchmarks.bench_live [ci|paper]
(exits non-zero on a convergence, consistency, or regression violation).

``--monitor`` attaches a :class:`repro.obs.monitor.HealthMonitor`:
convergence cells replay their (cached, deterministic) holdout-loss
curves through the drift watch, and the serve cell gets a **shadow
drive** — a separate learner+publisher+engine trio watched end to end
(staleness, publishes, windowed latency).  Health is sidecar-only; the
committed ``BENCH_live.json`` stays byte-identical under monitoring.
``--fault publish-stall`` stalls the shadow publisher after its first
snapshot (a ``staleness`` breach); ``--fault diverge`` adds a shadow
learner at 64x the step size whose loss curve blows up (a
``loss_divergence`` breach).  Faults never touch the measured cells.
"""
from __future__ import annotations

import hashlib
import platform
import statistics
import threading
import time

import numpy as np

from benchmarks import common
from repro.kernels import tune
from repro.live import (LiveConfig, LiveLearner, SnapshotPublisher,
                        SyntheticStream)
from repro.obs import metrics, trace
from repro.obs.monitor import DEFAULT_LIVE_SLOS, HealthMonitor
from repro.serve.glm import GLMScoreEngine, ScoreRequest
from repro.study.runner import TrialCache
from repro.study.spec import canonical_json
from repro.study.store import LiveBenchStore

#: bump to invalidate every cached measurement (protocol changes)
TIMING_SCHEMA = 1

TASK = "lr"

#: per-profile shape: stream width/depth, learner steps, and the
#: (replicas x compressed-merge) grid the convergence family sweeps
PROFILES = {
    "ci": dict(d=256, n_batch=64, n_steps=32, merge_every=4,
               step_size=0.2, replicas=(2, 4), compress=(False, True),
               serve_replicas=4, max_batch=8, n_checkpoints=4),
    "paper": dict(d=2048, n_batch=256, n_steps=128, merge_every=4,
                  step_size=0.1, replicas=(4, 8), compress=(False, True),
                  serve_replicas=8, max_batch=32, n_checkpoints=8),
}


def _digest(obj) -> str:
    return hashlib.sha256(canonical_json(obj).encode()).hexdigest()[:16]


def _learner(cfg, *, replicas, compress):
    stream = SyntheticStream(n_batch=cfg["n_batch"], d=cfg["d"], seed=0)
    lcfg = LiveConfig(task=TASK, replicas=replicas,
                      step_size=cfg["step_size"],
                      merge_every=cfg["merge_every"], compress=compress)
    return LiveLearner(lcfg, stream), stream


def _convergence_cell(cfg, *, replicas, compress) -> dict:
    """Holdout-loss-vs-wall-time of one learner config (measured)."""
    lrn, stream = _learner(cfg, replicas=replicas, compress=compress)
    ell, y = stream.holdout(512)
    lrn.run(2)                                  # warmup: jit compile
    lrn, stream = _learner(cfg, replicas=replicas, compress=compress)
    n_steps = cfg["n_steps"]
    ckpt = max(1, n_steps // cfg["n_checkpoints"])
    losses = [lrn.loss(ell, y)]
    t0 = time.perf_counter()
    for i in range(n_steps):
        lrn.step()
        if (i + 1) % ckpt == 0:
            losses.append(lrn.loss(ell, y))
    wall = time.perf_counter() - t0
    return {
        "losses": [round(float(v), 6) for v in losses],
        "wall_s": wall,
        "steps_per_s": n_steps / max(wall, 1e-9),
        "merges": lrn.merges,
    }


def _serve_cell(cfg) -> dict:
    """Latency + consistency of the scoring engine while a learner
    trains and publishes against it from another thread (measured)."""
    lrn, stream = _learner(cfg, replicas=cfg["serve_replicas"],
                           compress=False)
    engine = GLMScoreEngine(TASK, np.zeros(cfg["d"], np.float32),
                            ell_width=stream.ell_width,
                            max_batch=cfg["max_batch"],
                            queue_depth=4 * cfg["max_batch"],
                            flush_deadline_s=0.0)
    pub = SnapshotPublisher(engine, every_merges=1).attach(lrn)
    bound = pub.bound_steps(lrn.config.merge_every)
    max_staleness = 0
    done = threading.Event()

    def train():
        nonlocal max_staleness
        for _ in range(cfg["n_steps"]):
            lrn.step()
            lag = pub.staleness(lrn)
            if lag is not None:
                max_staleness = max(max_staleness, lag)
        done.set()

    rng = np.random.default_rng(1)
    k = stream.ell_width
    responses = []
    rid = 0
    # warmup the scoring launch before the clock starts
    engine.try_admit(ScoreRequest(-1, np.zeros(k), np.zeros(k, int)))
    engine.flush()
    th = threading.Thread(target=train)
    t0 = time.perf_counter()
    th.start()
    try:
        while not done.is_set():
            for _ in range(4):
                nn = int(rng.integers(1, k + 1))
                idx = rng.choice(cfg["d"], nn, replace=False)
                if engine.try_admit(ScoreRequest(rid, rng.normal(0, 1, nn),
                                                 idx)):
                    rid += 1
            responses.extend(engine.flush())
    finally:
        th.join()
    responses.extend(engine.drain())
    wall = time.perf_counter() - t0
    lat = sorted(r.latency_s for r in responses)
    versions = [r.model_version for r in responses]
    return {
        "p50_s": statistics.median(lat),
        "p99_s": lat[min(len(lat) - 1, int(0.99 * len(lat)))],
        "rps": len(lat) / max(wall, 1e-9),
        "n_scored": len(lat),
        "publishes": pub.publishes,
        "max_staleness_steps": int(max_staleness),
        "staleness_bound_steps": bound,
        "versions_monotone": versions == sorted(versions),
        "max_version_served": max(versions, default=0),
    }


def _shadow_serve_cell(cfg, mon: HealthMonitor, *,
                       publish_stall: bool = False) -> None:
    """Health-only serve drive: a fresh learner/publisher/engine trio is
    warmed, then watched end to end — per-step staleness, publishes,
    and windowed request latency all flow into ``mon``.  Single-loop
    interleave (step, admit, flush) so the drive is deterministic apart
    from wall time.  ``publish_stall`` freezes the publisher after its
    first snapshot: merges keep landing but nothing ships, so measured
    staleness climbs past the bound captured at attach time."""
    lrn, stream = _learner(cfg, replicas=cfg["serve_replicas"],
                           compress=False)
    engine = GLMScoreEngine(TASK, np.zeros(cfg["d"], np.float32),
                            ell_width=stream.ell_width,
                            max_batch=cfg["max_batch"],
                            queue_depth=4 * cfg["max_batch"],
                            flush_deadline_s=0.0)
    pub = SnapshotPublisher(engine, every_merges=1).attach(lrn)
    k = stream.ell_width
    engine.try_admit(ScoreRequest(-1, np.zeros(k), np.zeros(k, int)))
    engine.flush()                              # warm the scoring launch
    lrn.run(2)                                  # warm the step/merge launches
    mon.watch_live(lrn, pub).attach_engine(engine)
    rng = np.random.default_rng(1)
    rid = 0
    for _ in range(cfg["n_steps"]):
        lrn.step()
        if publish_stall and pub.publishes >= 1:
            pub.every_merges = 10 ** 9          # injected publisher stall
        for _ in range(2):
            nn = int(rng.integers(1, k + 1))
            idx = rng.choice(cfg["d"], nn, replace=False)
            if engine.try_admit(ScoreRequest(rid, rng.normal(0, 1, nn),
                                             idx)):
                rid += 1
        engine.flush()
    engine.drain()
    mon.roll()


def _shadow_diverge_cell(cfg, mon: HealthMonitor) -> None:
    """Health-only divergence driver: the convergence learner at 64x the
    profile step size, its holdout-loss curve fed to the drift watch.
    At that step size the logistic loss blows up within a handful of
    checkpoints — the ``loss_divergence`` fault class."""
    hot = {**cfg, "step_size": 64.0 * cfg["step_size"]}
    lrn, stream = _learner(hot, replicas=2, compress=False)
    ell, y = stream.holdout(256)
    ckpt = max(1, cfg["merge_every"])
    for i in range(cfg["n_steps"]):
        lrn.step()
        if (i + 1) % ckpt == 0:
            mon.observe_loss(lrn.loss(ell, y))
            mon.roll()


def _baseline(committed: dict | None, label: str, host: str,
              device_kind: str, field: str) -> float | None:
    """The committed trajectory's comparable point (same host + device)."""
    entry = (committed or {}).get("entries", {}).get(label)
    if (entry and entry.get("host") == host
            and entry.get("device_kind") == device_kind):
        return entry.get(field)
    return None


#: injectable fault classes for the monitored shadow cells
FAULTS = ("publish-stall", "diverge")


def run(profile: str = "ci", *, out_json: str = "BENCH_live.json",
        monitor: bool = False, fault: str | None = None):
    if fault is not None and fault not in FAULTS:
        raise ValueError(f"fault must be one of {FAULTS}: {fault!r}")
    if fault is not None and not monitor:
        raise ValueError("faults only affect monitored shadow cells; "
                         "pass monitor=True")
    mon = HealthMonitor(DEFAULT_LIVE_SLOS) if monitor else None
    try:
        committed = LiveBenchStore.load(out_json)
    except (FileNotFoundError, ValueError):
        committed = None
    store = LiveBenchStore(
        out_json, jsonl_path=common.RESULTS_DIR / "live_runs.jsonl")
    timing_cache = TrialCache(common.RESULTS_DIR / "live_cache")
    host = platform.node()
    device_kind = tune.device_kind()

    cfg = PROFILES[profile]
    rows = []

    def measure(label: str, kind: str, ident: dict, fn):
        key = _digest({"timing_schema": TIMING_SCHEMA, "label": label,
                       "profile": profile, "host": host,
                       "device_kind": device_kind, **ident})
        payload = timing_cache.peek(key)
        if payload is None:
            t0 = time.perf_counter()
            with trace.span("bench.live_cell", label=label, kind=kind):
                payload = fn()
            timing_cache.put(key, payload)
            store.record_event("live_timing", label=label,
                               cell_s=time.perf_counter() - t0, **payload)
            cached = False
        else:
            cached = True
        entry = {"kind": kind, "task": TASK, "d": cfg["d"],
                 "n_batch": cfg["n_batch"], "n_steps": cfg["n_steps"],
                 "merge_every": cfg["merge_every"], **ident, **payload,
                 "host": host, "device_kind": device_kind}
        store.record_entry(label, entry, cached=cached)
        return entry

    for replicas in cfg["replicas"]:
        for compress in cfg["compress"]:
            tag = "-c8" if compress else ""
            label = (f"live/{TASK}/d{cfg['d']}/r{replicas}"
                     f"-m{cfg['merge_every']}{tag}")
            ident = {"replicas": replicas, "compress": compress}
            entry = measure(
                label, "convergence", ident,
                lambda r=replicas, c=compress: _convergence_cell(
                    cfg, replicas=r, compress=c))
            rows.append({
                "label": label, **entry,
                "baseline_wall_s": _baseline(committed, label, host,
                                             device_kind, "wall_s"),
            })
            if mon is not None:
                # replay the (deterministic) curve through the drift
                # watch; one health window per convergence cell
                for v in entry["losses"]:
                    mon.observe_loss(v)
                mon.roll()

    label = (f"live-serve/{TASK}/d{cfg['d']}/r{cfg['serve_replicas']}"
             f"/batch{cfg['max_batch']}")
    entry = measure(label, "serve",
                    {"replicas": cfg["serve_replicas"],
                     "max_batch": cfg["max_batch"]},
                    lambda: _serve_cell(cfg))
    rows.append({
        "label": label, **entry,
        "baseline_p50_s": _baseline(committed, label, host, device_kind,
                                    "p50_s"),
    })
    if mon is not None:
        _shadow_serve_cell(cfg, mon,
                           publish_stall=(fault == "publish-stall"))
        if fault == "diverge":
            _shadow_diverge_cell(cfg, mon)

    out = store.write()
    print(f"wrote {out} ({len(rows)} trajectory points)")
    if mon is not None:
        print("\nhealth (shadow cells, sidecar-only):")
        print(mon.table())
        s = mon.summary()
        print(f"windows={s['windows']} breaches={s['total_breaches']} "
              f"{s['breaches'] or ''}")
        metrics.flush(0)
    return rows


if __name__ == "__main__":
    import argparse
    import sys

    from repro.study import claims

    ap = argparse.ArgumentParser()
    ap.add_argument("profile", nargs="?", default="ci",
                    choices=list(PROFILES))
    ap.add_argument("--monitor", action="store_true",
                    help="attach a HealthMonitor to shadow cells "
                         "(sidecar-only; BENCH_live.json unchanged)")
    ap.add_argument("--fault", choices=list(FAULTS), default=None,
                    help="inject a fault into the monitored shadow cells")
    ap.add_argument("--out-json", default="BENCH_live.json",
                    help="trajectory output path (CI fault runs point this "
                         "at scratch)")
    args = ap.parse_args()
    rows = run(args.profile, out_json=args.out_json, monitor=args.monitor,
               fault=args.fault)
    for r in rows:
        if r["kind"] == "convergence":
            print(f"  {r['label']:34s} loss={r['losses'][0]:8.3f}"
                  f"->{r['losses'][-1]:8.3f} steps/s={r['steps_per_s']:7.1f}"
                  f" merges={r['merges']}")
        else:
            print(f"  {r['label']:34s} p50={1e6 * r['p50_s']:9.1f}us "
                  f"p99={1e6 * r['p99_s']:9.1f}us rps={r['rps']:8.0f} "
                  f"staleness={r['max_staleness_steps']}"
                  f"<={r['staleness_bound_steps']} "
                  f"v<={r['max_version_served']}")
    bad = claims.check_bench_live(rows)
    if bad:
        print("VIOLATIONS:")
        for v in bad:
            print("  - " + v)
        sys.exit(1)
    print("live convergence + consistency + regression gate clean")
