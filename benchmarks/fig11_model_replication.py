"""Paper Fig 11/12: model-replication granularity (kernel/block/thread).

replicas=1 ≙ kernel (one shared model), 8 ≙ block, 64 ≙ thread.  Asserts the
paper's monotonic finding: statistical efficiency degrades with replication
while per-epoch cost (with merges amortized) improves or holds."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import sgd

LEVELS = {"kernel": 1, "block": 8, "thread": 64}


def run(profile: str = "ci"):
    p = common.PROFILES[profile]
    rows = []
    for name in common.profile_datasets(profile)[:2]:
        dspec = common.dataset_spec(name, profile)
        n = dspec.profile().n
        for task in ("lr",):
            per = {}
            for label, r in LEVELS.items():
                if n < r * 2:
                    continue
                strat = sgd.AsyncLocalSGD(replicas=r, local_batch=1)
                step, res, target = common.tune(
                    dspec, task, strat, p["epochs"], steps=(1e-2, 1e-1))
                per[label] = res
            best = min(float(np.nanmin(r.losses)) for r in per.values())
            target = best * 1.01 if best > 0 else best * 0.99
            for label, res in per.items():
                rows.append(dict(
                    dataset=name, task=task, replication=label,
                    replicas=LEVELS[label],
                    t_epoch_ms=1e3 * res.time_per_epoch,
                    epochs_to_1pct=res.epochs_to(target),
                    final_loss=float(res.losses[-1]),
                ))
    common.write_csv(rows, "fig11_model_replication.csv")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
