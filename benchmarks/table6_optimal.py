"""Paper Table 6: optimal configuration per dataset/task.

Delegates to ``repro.study.advisor`` — the subsystem's Table-6 search:
the design space {sync} ∪ {access path} × {replication level} × {rep-k}
is step-tuned per cell (§6.1) and ranked by measured time to 1% error
(``rank="measured"``, the paper's protocol) — reproducing the paper's
finding that the optimum is dataset/task-dependent (no single
configuration wins everywhere)."""
from __future__ import annotations

import math

from benchmarks import common
from repro.study import advisor


def run(profile: str = "ci"):
    p = common.PROFILES[profile]
    caps = advisor.HostCaps.detect()
    rows = []
    for name in common.profile_datasets(profile):
        dspec = common.dataset_spec(name, profile)
        for task in common.TASKS:
            rec = advisor.recommend(
                dspec, caps, task=task, runner=common.RUNNER,
                steps=(1e-2, 1e-1), epochs=max(6, p["epochs"] // 2),
                rank="measured")
            best = rec.best
            rows.append(dict(
                dataset=name, task=task, optimal_config=best.name,
                time_to_1pct_s=best.measured_time_to_target_s,
                n_configs_tried=len(rec.ranked),
                n_configs_converged=sum(
                    1 for r in rec.ranked if math.isfinite(r.score)),
            ))
    common.write_csv(rows, "table6_optimal.csv")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
