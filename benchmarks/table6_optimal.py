"""Paper Table 6: optimal async configuration per dataset/task.

Sweeps the design space {access path} x {replication level} x {rep-k} and
reports the configuration with the fastest time to 1% error — reproducing
the paper's finding that the optimum is dataset/task-dependent (no single
configuration wins everywhere)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import sgd


def space(n):
    for access in ("chunk", "round_robin"):
        for replicas in (4, 16, 64):
            if n < replicas * 2:
                continue
            for rep_k in (0, 10):
                yield sgd.AsyncLocalSGD(replicas=replicas, local_batch=1,
                                        access=access, rep_k=rep_k)


def run(profile: str = "ci"):
    p = common.PROFILES[profile]
    rows = []
    for name in p["datasets"]:
        ds = common.load(name, profile)
        for task in common.TASKS:
            results = {}
            for strat in space(ds.n):
                step, res, target = common.best_over_steps(
                    ds, task, strat, max(6, p["epochs"] // 2),
                    steps=(1e-2, 1e-1))
                results[strat.name] = (res, step)
            best_loss = min(float(np.nanmin(r.losses))
                            for r, _ in results.values())
            target = best_loss * 1.01 if best_loss > 0 else best_loss * 0.99
            scored = {}
            for label, (res, step) in results.items():
                t = res.time_to(target)
                scored[label] = (np.inf if t is None else t, res, step)
            opt = min(scored, key=lambda k: scored[k][0])
            rows.append(dict(
                dataset=name, task=task, optimal_config=opt,
                time_to_1pct_s=None if np.isinf(scored[opt][0])
                else scored[opt][0],
                n_configs_tried=len(scored),
                n_configs_converged=sum(1 for v in scored.values()
                                        if np.isfinite(v[0])),
            ))
    common.write_csv(rows, "table6_optimal.csv")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
