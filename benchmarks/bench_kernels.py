"""Kernel-level microbenchmarks: fused XLA GLM gradient vs the
primitive-composition baseline (wall time on this host), plus the Pallas
kernels' block configurations validated in interpret mode (correctness
only — interpret-mode wall time is not meaningful; TPU timing comes from
the roofline analysis of the dry-run artifacts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import glm
from repro.data import synthetic
from repro.kernels import common as kcommon
from repro.kernels.glm_grad import glm_grad
from repro.kernels.glm_grad.ref import glm_grad_ref
from repro.utils.timing import median_time


def run(profile: str = "ci"):
    rows = []
    for (n, d) in ((2048, 54), (1024, 300), (512, 2048)):
        ds = synthetic.make_dense(f"bench-{d}", n, d, seed=0)
        X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
        w = jnp.zeros(d)
        fused = jax.jit(lambda w: glm.grad_fused("lr", w, X, y))
        comp = jax.jit(lambda w: glm.grad_primitive_composition("lr", w, X, y))
        t_f = median_time(fused, w, warmup=1, iters=5)
        t_c = median_time(comp, w, warmup=1, iters=5)
        # kernel correctness at this shape on every dispatchable Pallas
        # backend (checking "reference" against the oracle would be vacuous)
        ref = glm_grad_ref("lr", w, X, y)
        checks = {}
        for b in kcommon.available_backends("glm_grad"):
            if b == kcommon.REFERENCE:
                continue
            out = glm_grad("lr", w, X, y, layout="row", block_rows=128,
                           backend=b)
            checks[f"match_{b.replace('-', '_')}"] = bool(
                np.allclose(out, ref, rtol=1e-3, atol=2e-3))
        rows.append(dict(n=n, d=d,
                         t_fused_us=1e6 * t_f, t_composition_us=1e6 * t_c,
                         fusion_speedup=t_c / t_f,
                         pallas_matches_ref=all(checks.values()), **checks))
    common.write_csv(rows, "bench_kernels.csv")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
