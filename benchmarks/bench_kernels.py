"""Kernel microbenchmark trajectory producer -> ``BENCH_kernels.json``.

One trajectory point per (kernel family, shape, dtype, block-config
variant): measured wall time on this host's auto-resolved backend, the
conformance verdict of every dispatchable Pallas flavor against the
family's oracle, and the analytic roofline annotation
(``repro.roofline.kernels``).  Variants cover the family's *default*
block geometry and the *tuned* geometry the autotuner cache picks
(``repro.kernels.tune``); fp32 rows add a bf16-input point.

Determinism contract (same as ``BENCH_study.json``): wall times are
cached in ``bench_results/kernel_cache`` keyed by the entry identity
(kernel, shape, dtype, variant, backend, host, device kind), and tuning
sweeps are cached in ``bench_results/tune_cache`` — a warm re-run reads
both caches and writes a byte-identical ``BENCH_kernels.json``, which CI
asserts.  The >20% regression gate (``claims.check_bench_kernels``)
compares each point against the *committed* trajectory entry with the
same label, host, and device kind — cross-host timings never gate — and
its baseline lookups stay out of the snapshot so the file remains a pure
function of the caches.

Standalone:  PYTHONPATH=src python -m benchmarks.bench_kernels [ci|paper]
(exits non-zero on a conformance or regression violation).
"""
from __future__ import annotations

import hashlib
import platform

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.data import synthetic
from repro.kernels import common as kcommon
from repro.kernels import tune
from repro.kernels.flash_attn import flash_attention
from repro.kernels.flash_attn.ref import attention_ref
from repro.kernels.glm_grad import glm_grad
from repro.kernels.glm_grad.ref import glm_grad_ref
from repro.kernels.glm_sgd import glm_sgd_epoch
from repro.kernels.glm_sgd.ref import glm_sgd_epoch_ref
from repro.kernels.glm_sgd_sparse import ell_sgd_epoch
from repro.kernels.glm_sgd_sparse.ref import ell_sgd_epoch_ref
from repro.kernels.glm_sparse import ell_glm_grad
from repro.kernels.glm_sparse.ref import ell_glm_grad_ref
from repro.roofline import kernels as roofline
from repro.study.runner import TrialCache
from repro.study.spec import canonical_json
from repro.study.store import KernelBenchStore
from repro.utils.timing import time_stats

#: bump to invalidate every cached wall time (timing protocol changes)
TIMING_SCHEMA = 1

STEP = 0.05  # SGD-epoch step size (a compile-time constant, not tuned)

# family -> per-profile benchmark shape
SHAPES = {
    "glm_grad": {"ci": dict(n=512, d=128), "paper": dict(n=4096, d=512)},
    "glm_sgd": {"ci": dict(n=256, d=64), "paper": dict(n=2048, d=256)},
    "glm_sparse": {"ci": dict(n=256, d=512, k=8),
                   "paper": dict(n=2048, d=4096, k=16)},
    "glm_sgd_sparse": {"ci": dict(n=128, d=256, k=8),
                       "paper": dict(n=1024, d=1024, k=16)},
    "flash_attn": {
        "ci": dict(batch=1, heads_q=2, heads_kv=1, seq_q=64, seq_k=64,
                   head_dim=32),
        "paper": dict(batch=2, heads_q=4, heads_kv=2, seq_q=256, seq_k=256,
                      head_dim=64),
    },
}

#: (dtype, variant) trajectory points per family; the tuned variant only
#: makes sense where the caches can pin a winner, and bf16 tracks input-
#: cast cost at the default geometry
VARIANTS = (("float32", "default"), ("float32", "tuned"),
            ("bfloat16", "default"))


def _digest(obj) -> str:
    return hashlib.sha256(canonical_json(obj).encode()).hexdigest()[:16]


def _shape_tag(shape: dict) -> str:
    return "-".join(f"{k}{v}" for k, v in sorted(shape.items()))


class _Family:
    """One family's benchmark closure set at a concrete shape + dtype."""

    def __init__(self, info, call, oracle, tol):
        self.info = info          # dispatch/tuner/roofline call info
        self.call = call          # call(backend=..., **cfg) -> jax value
        self.oracle = oracle      # oracle() -> reference output
        self.tol = tol            # (rtol, atol) for the conformance check


def _make_family(kernel: str, shape: dict, dtype: str) -> _Family:
    jdt = jnp.dtype(dtype)
    loose = dtype == "bfloat16"
    tol = (0.05, 0.05) if loose else (1e-3, 2e-3)
    rng = np.random.default_rng(7)

    if kernel in ("glm_grad", "glm_sgd"):
        n, d = shape["n"], shape["d"]
        ds = synthetic.make_dense(f"bench-{kernel}-{d}", n, d, seed=0)
        X = jnp.asarray(ds.X, dtype=jdt)
        y = jnp.asarray(ds.y, dtype=jdt)
        w = jnp.asarray(rng.normal(0, 0.1, d), dtype=jdt)
        info = {"dtype": dtype, "n": n, "d": d}
        if kernel == "glm_grad":
            call = lambda backend=None, **cfg: glm_grad(  # noqa: E731
                "lr", w, X, y, backend=backend, **cfg)
            oracle = lambda: glm_grad_ref(  # noqa: E731
                "lr", *(a.astype(jnp.float32) for a in (w, X, y)))
        else:
            call = lambda backend=None, **cfg: glm_sgd_epoch(  # noqa: E731
                "lr", w, X, y, step=STEP, backend=backend, **cfg)
            oracle = lambda: glm_sgd_epoch_ref(  # noqa: E731
                "lr", *(a.astype(jnp.float32) for a in (w, X, y)), STEP, 8)
            info["micro_batch"] = 8  # oracle comparison fixes the default
        return _Family(info, call, oracle, tol)

    if kernel in ("glm_sparse", "glm_sgd_sparse"):
        n, d, k = shape["n"], shape["d"], shape["k"]
        ds = synthetic.make_sparse(f"bench-{kernel}-{d}", n, d, k * 0.6, k,
                                   seed=0)
        vals = jnp.asarray(ds.ell.values, dtype=jdt)
        idx = jnp.asarray(ds.ell.indices)
        y = jnp.asarray(ds.y, dtype=jdt)
        w = jnp.asarray(rng.normal(0, 0.1, d), dtype=jdt)
        info = {"dtype": dtype, "sparse": True, "n": n, "d": d, "k": k}
        f32 = lambda: (w.astype(jnp.float32), vals.astype(jnp.float32),  # noqa: E731
                       idx, y.astype(jnp.float32))
        if kernel == "glm_sparse":
            call = lambda backend=None, **cfg: ell_glm_grad(  # noqa: E731
                "lr", w, vals, idx, y, backend=backend, **cfg)
            oracle = lambda: ell_glm_grad_ref("lr", *f32())  # noqa: E731
        else:
            call = lambda backend=None, **cfg: ell_sgd_epoch(  # noqa: E731
                "lr", w, vals, idx, y, step=STEP, backend=backend, **cfg)
            oracle = lambda: ell_sgd_epoch_ref(  # noqa: E731
                "lr", *f32(), STEP, 8)
            info["micro_batch"] = 8
        return _Family(info, call, oracle, tol)

    assert kernel == "flash_attn", kernel
    b, hq, hkv = shape["batch"], shape["heads_q"], shape["heads_kv"]
    sq, sk, hd = shape["seq_q"], shape["seq_k"], shape["head_dim"]
    q = jnp.asarray(rng.normal(0, 1, (b, hq, sq, hd)), dtype=jdt)
    kk = jnp.asarray(rng.normal(0, 1, (b, hkv, sk, hd)), dtype=jdt)
    v = jnp.asarray(rng.normal(0, 1, (b, hkv, sk, hd)), dtype=jdt)
    info = {"dtype": dtype, "head_dim": hd, "seq_q": sq, "seq_k": sk,
            **shape}
    call = lambda backend=None, **cfg: flash_attention(  # noqa: E731
        q, kk, v, causal=True, backend=backend, **cfg)
    rep = hq // hkv
    oracle = lambda: attention_ref(  # noqa: E731
        q.astype(jnp.float32),
        jnp.repeat(kk, rep, 1).astype(jnp.float32),
        jnp.repeat(v, rep, 1).astype(jnp.float32), causal=True)
    return _Family(info, call, oracle, tol)


def _conformance(kernel: str, fam: _Family) -> tuple[bool | None, list[str]]:
    """Every dispatchable non-reference flavor vs the oracle.

    Returns ``(verdict, checked_backends)`` where the verdict is None —
    not True — when no Pallas flavor could be checked at this shape (the
    old ``all({})`` fast-path green-lit exactly that case).
    """
    ref = np.asarray(fam.oracle(), dtype=np.float32)
    checks = {}
    for b in kcommon.available_backends(kernel, info=fam.info):
        if b == kcommon.REFERENCE:
            continue
        out = np.asarray(fam.call(backend=b), dtype=np.float32)
        rtol, atol = fam.tol
        checks[b] = bool(np.allclose(out, ref, rtol=rtol, atol=atol))
    if not checks:
        return None, []
    return all(checks.values()), sorted(checks)


def _baseline_wall(committed: dict | None, label: str, host: str,
                   device_kind: str) -> float | None:
    """The committed trajectory's comparable point (same host + device)."""
    entry = (committed or {}).get("entries", {}).get(label)
    if (entry and entry.get("host") == host
            and entry.get("device_kind") == device_kind):
        return entry.get("wall_s")
    return None


def run(profile: str = "ci", *, out_json: str = "BENCH_kernels.json"):
    try:
        committed = KernelBenchStore.load(out_json)
    except (FileNotFoundError, ValueError):
        committed = None
    store = KernelBenchStore(
        out_json, jsonl_path=common.RESULTS_DIR / "kernel_runs.jsonl")
    timing_cache = TrialCache(common.RESULTS_DIR / "kernel_cache")
    tune_cache = tune.TuneCache(common.RESULTS_DIR / "tune_cache")
    host = platform.node()
    device_kind = tune.device_kind()

    rows = []
    for kernel, shapes in SHAPES.items():
        shape = shapes[profile]
        tag = _shape_tag(shape)
        verdicts: dict[str, tuple] = {}
        for dtype, variant in VARIANTS:
            fam = _make_family(kernel, shape, dtype)
            backend = kcommon.resolve_backend(kernel, info=fam.info)
            if dtype not in verdicts:
                verdicts[dtype] = _conformance(kernel, fam)
            pallas_match, checked = verdicts[dtype]

            config: dict = {}
            if variant == "tuned":
                config = dict(tune.tune(kernel, backend, fam.info, fam.call,
                                        cache=tune_cache)["config"])

            label = f"{kernel}/{tag}/{dtype}/{variant}"
            key = _digest({"timing_schema": TIMING_SCHEMA, "label": label,
                           "profile": profile, "backend": backend,
                           "config": config, "host": host,
                           "device_kind": device_kind})
            payload = timing_cache.peek(key)
            if payload is None:
                stats = time_stats(lambda: fam.call(**config),
                                   warmup=1, iters=5)
                # the snapshot commits only the median (deterministic via
                # the timing cache); dispersion goes to the JSONL sidecar
                payload = {"wall_s": stats["median"]}
                timing_cache.put(key, payload)
                cached = False
                store.record_event("timing_stats", label=label, **stats)
            else:
                cached = True

            entry = {
                "kernel": kernel,
                "shape": dict(sorted(shape.items())),
                "dtype": dtype,
                "variant": variant,
                "backend": backend,
                "config": config,
                "wall_s": payload["wall_s"],
                "pallas_match": pallas_match,
                "checked_backends": checked,
                "roofline": roofline.annotate(kernel, fam.info,
                                              payload["wall_s"]),
                "host": host,
                "device_kind": device_kind,
            }
            store.record_entry(label, entry, cached=cached)
            rows.append({
                "label": label, **entry,
                "baseline_wall_s": _baseline_wall(committed, label, host,
                                                  device_kind),
            })
    out = store.write()
    print(f"wrote {out} ({len(rows)} trajectory points)")
    return rows


if __name__ == "__main__":
    import sys

    from repro.study import claims

    profile = sys.argv[1] if len(sys.argv) > 1 else "ci"
    rows = run(profile)
    for r in rows:
        print(f"  {r['label']:48s} {1e6 * r['wall_s']:10.1f}us "
              f"match={r['pallas_match']} "
              f"bound={r['roofline']['bound']}")
    bad = claims.check_bench_kernels(rows)
    if bad:
        print("VIOLATIONS:")
        for v in bad:
            print("  - " + v)
        sys.exit(1)
    print("kernel conformance + regression gate clean")
