"""Paper Fig 8/9: data-access-path selection (row/col x rr/ch).

Two measurements:
  1. engine level — chunk vs round-robin example assignment: hardware
     efficiency (time/epoch) and statistical efficiency (epochs to target);
  2. kernel level — row vs col layout of the fused GLM gradient kernel
     (Pallas, interpret mode on CPU: correctness + blocking structure; the
     layout trade is a VMEM/lane-alignment property recorded for TPU).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import sgd


def run(profile: str = "ci"):
    p = common.PROFILES[profile]
    rows = []
    for name in common.profile_datasets(profile)[:2]:
        dspec = common.dataset_spec(name, profile)
        for task in ("lr",):
            per = {}
            for access in ("chunk", "round_robin"):
                strat = sgd.AsyncLocalSGD(replicas=8, local_batch=1,
                                          access=access)
                step, res, target = common.tune(
                    dspec, task, strat, p["epochs"])
                per[access] = (res, target)
            best = min(float(np.nanmin(r.losses)) for r, _ in per.values())
            target = best * 1.01 if best > 0 else best * 0.99
            for access, (res, _) in per.items():
                rows.append(dict(
                    dataset=name, task=task, access=access,
                    t_epoch_ms=1e3 * res.time_per_epoch,
                    epochs_to_1pct=res.epochs_to(target),
                    time_to_1pct_s=res.time_to(target),
                ))
    common.write_csv(rows, "fig8_access_path.csv")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
