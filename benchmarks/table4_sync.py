"""Paper Table 4/5: synchronous SGD — time to convergence, time/iteration,
#iterations; speedups of fused vs primitive-composition vs sequential.

Execution paths (DESIGN.md §2): ``seq`` (incremental, the paper's cpu-seq),
``sync-comp`` (primitive composition with materialization barriers — the
ViennaCL/TF/BIDMach analogue) and ``sync`` (fused gradient — our kernel).
The paper's headline claims asserted here:
  * sync statistical efficiency is identical across execution paths;
  * fused beats composition in time/iteration (hardware efficiency);
  * parallel (vectorized batch) crushes sequential by orders of magnitude.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import glm, sgd
from repro.utils.timing import median_time


def _sync_paths(ds, task, step):
    """time/iteration for the three execution paths on one dataset."""
    if ds.dense:
        X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    else:
        from repro.core import sparse
        X, y = sparse.to_dense(ds.ell), jnp.asarray(ds.y)
        if X.shape[0] * X.shape[1] > 5e7:   # densification cap (news-style)
            X, y = X[:1024], y[:1024]
    w = jnp.zeros(X.shape[1])

    fused = jax.jit(lambda w: w - step * glm.grad_fused(task, w, X, y))
    comp = jax.jit(
        lambda w: w - step * glm.grad_primitive_composition(task, w, X, y))
    seq = jax.jit(lambda w: glm.incremental_epoch(task, w, X, y, step))

    out = {}
    out["sync"] = median_time(fused, w, warmup=1, iters=3)
    out["sync-comp"] = median_time(comp, w, warmup=1, iters=3)
    out["seq"] = median_time(seq, w, warmup=1, iters=3)
    # statistical-efficiency identity: same loss trajectory fused vs comp
    wf, wc = w, w
    for _ in range(3):
        wf, wc = fused(wf), comp(wc)
    out["_path_equiv"] = bool(np.allclose(wf, wc, rtol=1e-3, atol=1e-3))
    return out


def run(profile: str = "ci"):
    p = common.PROFILES[profile]
    rows = []
    for name in common.profile_datasets(profile):
        dspec = common.dataset_spec(name, profile)
        ds = common.load(name, profile)
        for task in common.TASKS:
            t = _sync_paths(ds, task, 1e-3)
            strategy = sgd.SyncSGD()
            step, res, target = common.tune(
                dspec, task, strategy, p["epochs"])
            iters = res.epochs_to(target)
            rows.append(dict(
                dataset=name, task=task, n=ds.n,
                t_iter_sync_ms=1e3 * t["sync"],
                t_iter_comp_ms=1e3 * t["sync-comp"],
                t_iter_seq_ms=1e3 * t["seq"],
                speedup_fused_vs_comp=t["sync-comp"] / t["sync"],
                speedup_sync_vs_seq=t["seq"] / t["sync"],
                iters_to_1pct=iters,
                time_to_1pct_s=res.time_to(target),
                best_step=step,
                paths_statistically_identical=t["_path_equiv"],
            ))
    common.write_csv(rows, "table4_sync.csv")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
