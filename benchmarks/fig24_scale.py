"""Paper Fig 24/25: scalability in #examples (N) and #features (d).

Asserts ~linear time/epoch growth in N and records growth in d; the
relative ordering of the algorithms is expected to be preserved."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import glm, sgd
from repro.data import synthetic
from repro.utils.timing import median_time


def run(profile: str = "ci"):
    small = profile == "ci"
    rows = []
    # scale N at fixed d (covtype-style dense)
    for n in ((512, 1024, 2048) if small else (2048, 8192, 16384)):
        ds = synthetic.make_dense("covtype-n", n, 54, seed=0)
        X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
        w = jnp.zeros(54)
        sync = jax.jit(lambda w: w - 1e-3 * glm.grad_fused("lr", w, X, y))
        t_sync = median_time(sync, w, warmup=1, iters=3)
        prob = glm.GLMProblem("lr", X, y, 1e-2)
        res = sgd.run(prob, sgd.AsyncLocalSGD(replicas=8, local_batch=1), 4)
        rows.append(dict(axis="N", value=n, d=54,
                         t_epoch_sync_ms=1e3 * t_sync,
                         t_epoch_async_ms=1e3 * res.time_per_epoch))
    # scale d at fixed N
    for d in ((32, 128, 512) if small else (54, 300, 2048)):
        ds = synthetic.make_dense("dense-d", 1024, d, seed=1)
        X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
        w = jnp.zeros(d)
        sync = jax.jit(lambda w: w - 1e-3 * glm.grad_fused("lr", w, X, y))
        t_sync = median_time(sync, w, warmup=1, iters=3)
        prob = glm.GLMProblem("lr", X, y, 1e-2)
        res = sgd.run(prob, sgd.AsyncLocalSGD(replicas=8, local_batch=1), 4)
        rows.append(dict(axis="d", value=d, d=d,
                         t_epoch_sync_ms=1e3 * t_sync,
                         t_epoch_async_ms=1e3 * res.time_per_epoch))
    common.write_csv(rows, "fig24_scale.csv")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
