"""Paper Fig 24/25: scalability in #examples (N) and #features (d).

Asserts ~linear time/epoch growth in N and records growth in d; the
relative ordering of the algorithms is expected to be preserved.  Async
epochs run as study trials (explicit-shape ``DatasetSpec``s, so the
scaling sweep is cached/resumable); the sync point is a direct fused-
gradient timing."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import glm, sgd
from repro.study import spec as spec_mod
from repro.utils.timing import median_time


def _point(axis: str, name: str, n: int, d: int, seed: int):
    dspec = spec_mod.DatasetSpec(name, n=n, d=d, seed=seed)
    ds = common.RUNNER.dataset(dspec)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    w = jnp.zeros(d)
    sync = jax.jit(lambda w: w - 1e-3 * glm.grad_fused("lr", w, X, y))
    t_sync = median_time(sync, w, warmup=1, iters=3)
    trial = spec_mod.TrialSpec(
        dataset=dspec, task="lr",
        strategy=sgd.AsyncLocalSGD(replicas=8, local_batch=1),
        step=1e-2, epochs=4)
    res = common.RUNNER.run_trial(trial)
    return dict(axis=axis, value=(n if axis == "N" else d), d=d,
                t_epoch_sync_ms=1e3 * t_sync,
                t_epoch_async_ms=1e3 * res.time_per_epoch)


def run(profile: str = "ci"):
    small = profile == "ci"
    rows = []
    # scale N at fixed d (covtype-style dense)
    for n in ((512, 1024, 2048) if small else (2048, 8192, 16384)):
        rows.append(_point("N", "covtype-n", n, 54, seed=0))
    # scale d at fixed N
    for d in ((32, 128, 512) if small else (54, 300, 2048)):
        rows.append(_point("d", "dense-d", 1024, d, seed=1))
    common.write_csv(rows, "fig24_scale.csv")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
