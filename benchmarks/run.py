"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--profile ci|paper]
        [--only mod1,mod2] [--real] [--workers N]
        [--out-json BENCH_study.json]

``--only`` accepts unambiguous prefixes (``--only table4`` runs
``table4_sync``).  ``--real`` sweeps the paper's measured datasets via
repro.data.ingest instead of the synthetic Table-3 stand-ins; offline
it resolves the bundled fixtures, and trial-cache keys carry the
ingested content hash either way.  ``--workers N`` dispatches
cache-miss trials across N local worker subprocesses (repro.sweep):
shards are stack-aware, dead workers are requeued, and the per-worker
caches merge into the canonical trial cache — so the store output is
byte-identical to a single-host run over the same cache.  Dispatch
happens per runner call: batched sweeps (table6_optimal's advisor
space) fan out across the workers, while single-grid calls run
in-process as usual — never slower than serial (docs/SWEEPS.md).

Emits CSVs into bench_results/ and prints a summary, then validates the
paper's qualitative claims (repro.study.claims) against the measured
rows (exit 1 on violation).  Every trial the sweep executes is recorded
through repro.study.store into the structured results file (--out-json,
default BENCH_study.json) plus an append-only JSONL run log — the repo's
machine-readable perf trajectory.  Trials are cached under
bench_results/study_cache/: re-running a finished sweep is a pure cache
read and reproduces BENCH_study.json byte-for-byte.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_kernels, bench_live, bench_serve, common,
                        fig8_access_path, fig11_model_replication,
                        fig14_data_replication, fig22_sync_vs_async,
                        fig24_scale, table4_sync, table6_optimal,
                        table7_async)
from repro.obs import trace
from repro.study import claims
from repro.study.store import StudyStore

MODULES = {
    "table4_sync": table4_sync,
    "table6_optimal": table6_optimal,
    "table7_async": table7_async,
    "fig8_access_path": fig8_access_path,
    "fig11_model_replication": fig11_model_replication,
    "fig14_data_replication": fig14_data_replication,
    "fig22_sync_vs_async": fig22_sync_vs_async,
    "fig24_scale": fig24_scale,
    "bench_kernels": bench_kernels,
    "bench_serve": bench_serve,
    "bench_live": bench_live,
}


def _resolve_module(name: str) -> str | list[str]:
    """Exact module name, or an unambiguous prefix of one.

    Returns the resolved name, or the (possibly empty) list of
    colliding candidates so the caller can report ambiguity vs unknown.
    """
    if name in MODULES:
        return name
    hits = [m for m in MODULES if m.startswith(name)]
    return hits[0] if len(hits) == 1 else hits


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default="ci", choices=list(common.PROFILES))
    ap.add_argument("--only", default=None,
                    help="comma-separated module names or unambiguous "
                         "prefixes (default: all)")
    ap.add_argument("--real", action="store_true",
                    help="sweep real datasets (repro.data.ingest) instead "
                         "of the synthetic Table-3 stand-ins")
    ap.add_argument("--workers", type=int, default=1,
                    help="dispatch cache-miss trials across N local worker "
                         "subprocesses (repro.sweep; 1 = in-process)")
    ap.add_argument("--out-json", default="BENCH_study.json",
                    help="structured results path (repro.study.store)")
    args = ap.parse_args(argv)

    if args.workers < 1:
        ap.error(f"--workers must be >= 1: {args.workers}")
    if args.real:
        common.set_source("real")
    if args.workers > 1:
        from repro.sweep import LocalProcessExecutor
        common.RUNNER.executor = LocalProcessExecutor(
            workers=args.workers,
            work_dir=common.RESULTS_DIR / "sweep_workers")

    selected = list(MODULES)
    if args.only:
        asked = [s.strip() for s in args.only.split(",") if s.strip()]
        resolved = {s: _resolve_module(s) for s in asked}
        for s, m in resolved.items():
            if isinstance(m, list):
                if m:
                    ap.error(f"ambiguous module prefix {s!r}: matches {m}")
                ap.error(f"unknown module {s!r}; known: {list(MODULES)}")
        selected = [resolved[s] for s in asked]

    store = StudyStore(args.out_json,
                       jsonl_path=common.RESULTS_DIR / "study_runs.jsonl")
    common.RUNNER.store = store

    if trace.enabled():
        print(f"tracing -> {trace.current_path()}", flush=True)

    results = {}
    t00 = time.time()
    for name in selected:
        t0 = time.time()
        print(f"== {name} ==", flush=True)
        with trace.span("bench.module", module=name, profile=args.profile):
            results[name] = MODULES[name].run(args.profile)
        for row in results[name]:
            print("  " + ", ".join(f"{k}={common.fmt(v)}"
                                   for k, v in row.items()))
        print(f"   ({time.time()-t0:.1f}s)")

    violations = claims.validate(results)
    store.record_claims(violations, checked_modules=list(results))
    out = store.write()
    print(f"\ntotal {time.time()-t00:.1f}s; "
          f"{sum(len(v) for v in results.values())} rows; "
          f"{len(store.trials)} trials -> {out} "
          f"({common.RUNNER.cache.hits} cache hits)")
    if violations:
        print("PAPER-CLAIM VIOLATIONS:")
        for v in violations:
            print("  - " + v)
        sys.exit(1)
    print("all paper-claim checks passed")


if __name__ == "__main__":
    main()
