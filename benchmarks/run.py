"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--profile ci|paper] [--only X]

Emits CSVs into bench_results/ and prints a summary, then validates the
paper's qualitative claims against the measured rows (exit 1 on violation).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_kernels, common, fig8_access_path,
                        fig11_model_replication, fig14_data_replication,
                        fig22_sync_vs_async, fig24_scale, table4_sync,
                        table6_optimal, table7_async)

MODULES = {
    "table4_sync": table4_sync,
    "table6_optimal": table6_optimal,
    "table7_async": table7_async,
    "fig8_access_path": fig8_access_path,
    "fig11_model_replication": fig11_model_replication,
    "fig14_data_replication": fig14_data_replication,
    "fig22_sync_vs_async": fig22_sync_vs_async,
    "fig24_scale": fig24_scale,
    "bench_kernels": bench_kernels,
}


def validate(results: dict) -> list[str]:
    """Paper-claim checks over the measured rows; returns violations."""
    bad = []

    for r in results.get("table4_sync", []):
        if not r["paths_statistically_identical"]:
            bad.append(f"table4: fused != composition on {r['dataset']}"
                       f"/{r['task']} (sync statistical identity broken)")
        if r["speedup_sync_vs_seq"] < 1.0:
            bad.append(f"table4: batch path slower than sequential on "
                       f"{r['dataset']}/{r['task']}")

    # model replication: more replicas never improves statistical efficiency
    by_key = {}
    for r in results.get("fig11_model_replication", []):
        by_key.setdefault((r["dataset"], r["task"]), []).append(r)
    for key, rs in by_key.items():
        rs = sorted(rs, key=lambda r: r["replicas"])
        losses = [r["final_loss"] for r in rs]
        if losses[-1] < losses[0] * 0.98:   # thread beating kernel outright
            bad.append(f"fig11: replication improved statistical efficiency "
                       f"on {key} (unexpected): {losses}")

    # data replication: rep-k costs hardware efficiency
    by_key = {}
    for r in results.get("fig14_data_replication", []):
        by_key.setdefault((r["dataset"], r["task"]), []).append(r)
    for key, rs in by_key.items():
        rs = sorted(rs, key=lambda r: r["rep_k"])
        # single-core CI timings are noisy at sub-ms epochs: only flag a
        # clear (>=30%) inversion of the expected rep-k hardware cost
        if rs[-1]["t_epoch_ms"] < rs[0]["t_epoch_ms"] * 0.7:
            bad.append(f"fig14: rep-10 cheaper than rep-0 on {key}")

    for r in results.get("bench_kernels", []):
        if not r["pallas_matches_ref"]:
            bad.append(f"kernels: pallas mismatch at n={r['n']} d={r['d']}")

    n_rows = [r for r in results.get("fig24_scale", []) if r["axis"] == "N"]
    if len(n_rows) >= 2:
        t0, t1 = n_rows[0], n_rows[-1]
        growth = t1["t_epoch_async_ms"] / max(t0["t_epoch_async_ms"], 1e-9)
        size = t1["value"] / t0["value"]
        if growth > size * 3:
            bad.append(f"fig24: async time grew {growth:.1f}x for {size:.0f}x "
                       f"data (super-linear)")
    return bad


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default="ci", choices=list(common.PROFILES))
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    results = {}
    t00 = time.time()
    for name, mod in MODULES.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        print(f"== {name} ==", flush=True)
        results[name] = mod.run(args.profile)
        for row in results[name]:
            print("  " + ", ".join(f"{k}={common.fmt(v)}"
                                   for k, v in row.items()))
        print(f"   ({time.time()-t0:.1f}s)")

    violations = validate(results)
    print(f"\ntotal {time.time()-t00:.1f}s; "
          f"{sum(len(v) for v in results.values())} rows")
    if violations:
        print("PAPER-CLAIM VIOLATIONS:")
        for v in violations:
            print("  - " + v)
        sys.exit(1)
    print("all paper-claim checks passed")


if __name__ == "__main__":
    main()
