"""Real-dataset ingestion: parser edge cases, cache integrity, registry
fidelity against Table 3, and the DatasetSpec(source="real") wiring.

The acceptance contract (ISSUE 3): every paper dataset name resolves
offline from the bundled fixtures into a CSR/ELL matrix whose profile
(n, d, density, task) matches Table 3, and real trials are cache-keyed
by the ingested content hash.
"""
import bz2
import io

import numpy as np
import pytest

from repro.core import sgd
from repro.core import sparse as sparse_mod
from repro.data import ingest
from repro.data.ingest import cache, libsvm, registry
from repro.study import spec
from repro.study.runner import Runner

# Table 3 of the paper, asserted literally (n, d, avg_nnz, dense, task)
TABLE3 = {
    "covtype": (581_012, 54, 54.0, True, "binary"),
    "w8a": (64_700, 300, 11.65, False, "binary"),
    "real-sim": (72_309, 20_958, 51.30, False, "binary"),
    "news": (19_996, 1_355_191, 454.99, False, "binary"),
    "skin": (245_057, 3, 3.0, True, "binary"),
}


@pytest.fixture
def isolated_env(tmp_path, monkeypatch):
    """Point the blob cache at a tmp dir and clear in-process memos."""
    monkeypatch.setenv("REPRO_DATA_DIR", str(tmp_path / "data"))
    monkeypatch.delenv("REPRO_ALLOW_DOWNLOAD", raising=False)
    ingest.clear_cache()
    yield tmp_path
    ingest.clear_cache()


# ---------------------------------------------------------------------------
# libsvm parser edge cases
# ---------------------------------------------------------------------------


def _parse(text, **kw):
    return libsvm.parse_lines(io.StringIO(text).readlines(), **kw)


def test_parser_skips_blank_lines_comments_and_trailing_whitespace():
    csr, y = _parse(
        "\n"
        "# full-line comment\n"
        "+1 1:0.5 3:1.5   \t \n"           # trailing whitespace
        "   \n"
        "-1 2:2.0  # trailing comment\n")
    assert csr.n == 2 and y.tolist() == [1.0, -1.0]
    np.testing.assert_allclose(csr.to_dense(),
                               [[0.5, 0.0, 1.5], [0.0, 2.0, 0.0]])


def test_parser_one_based_by_default_zero_based_detected():
    one, _ = _parse("1 1:1.0 2:2.0\n")
    assert one.d == 2 and one.to_dense().tolist() == [[1.0, 2.0]]
    zero, _ = _parse("1 0:1.0 2:2.0\n1 1:5.0\n")   # a 0 index anywhere
    assert zero.d == 3
    np.testing.assert_allclose(zero.to_dense(),
                               [[1.0, 0.0, 2.0], [0.0, 5.0, 0.0]])
    forced, _ = _parse("1 1:1.0 2:2.0\n", zero_based=True)
    assert forced.d == 3  # same tokens, forced reading
    with pytest.raises(libsvm.LibsvmFormatError, match="forced to 1-based"):
        _parse("1 0:5.0 1:7.0\n", zero_based=False)   # 0 can't shift down


def test_parser_label_only_rows_are_zero_examples():
    csr, y = _parse("1 1:1.0\n-1\n1 2:3.0\n", d=2)
    assert csr.n == 3
    assert csr.row_nnz.tolist() == [1, 0, 1]
    np.testing.assert_allclose(csr.to_dense()[1], [0.0, 0.0])
    assert y.tolist() == [1.0, -1.0, 1.0]


def test_parser_sums_duplicate_feature_ids():
    csr, _ = _parse("1 3:1.0 1:2.0 3:0.25\n")
    np.testing.assert_allclose(csr.to_dense(), [[2.0, 0.0, 1.25]])
    assert csr.row_nnz.tolist() == [2]          # merged, not repeated


def test_parser_ignores_qid_and_rejects_garbage():
    csr, _ = _parse("1 qid:7 1:1.0\n")
    assert csr.nnz == 1
    with pytest.raises(libsvm.LibsvmFormatError, match="bad label"):
        _parse("spam 1:1.0\n")
    with pytest.raises(libsvm.LibsvmFormatError, match="bad feature"):
        _parse("1 1:one\n")
    with pytest.raises(libsvm.LibsvmFormatError, match="out of range"):
        _parse("1 5:1.0\n", d=2)


def test_parser_streams_bz2(tmp_path):
    path = tmp_path / "mini.bz2"
    with bz2.open(path, "wt") as f:
        f.write("1 1:0.5\n-1 2:0.5\n")
    csr, y = libsvm.parse_file(path)
    assert csr.n == 2 and y.tolist() == [1.0, -1.0]


def test_write_libsvm_round_trips(tmp_path):
    csr = sparse_mod.from_csr_parts(
        [np.array([0, 4]), np.array([], dtype=np.int64), np.array([2])],
        [np.array([1.5, -2.0]), np.array([], dtype=np.float32),
         np.array([0.125])], d=6)
    y = np.array([1.0, -1.0, 1.0], dtype=np.float32)
    path = tmp_path / "rt.libsvm"
    libsvm.write_libsvm(path, csr, y)
    back, y2 = libsvm.parse_file(path, d=6)
    np.testing.assert_allclose(back.to_dense(), csr.to_dense())
    np.testing.assert_array_equal(y2, y)


# ---------------------------------------------------------------------------
# CSR layout helpers (core/sparse.py)
# ---------------------------------------------------------------------------


def test_csr_select_and_ell_conversion():
    csr = sparse_mod.from_csr_parts(
        [np.array([0, 1]), np.array([2]), np.array([0, 1, 2])],
        [np.array([1.0, 2.0]), np.array([3.0]), np.array([4.0, 5.0, 6.0])],
        d=3)
    sub = csr.select(np.array([2, 0]))
    np.testing.assert_allclose(sub.to_dense(),
                               [[4.0, 5.0, 6.0], [1.0, 2.0, 0.0]])
    ell = sub.to_ell()
    assert ell.max_nnz == 3
    np.testing.assert_allclose(np.asarray(sparse_mod.to_dense(ell)),
                               sub.to_dense())
    truncated = csr.to_ell(pad_to=1)            # explicit pad truncates
    assert truncated.max_nnz == 1
    np.testing.assert_allclose(np.asarray(truncated.values)[:, 0],
                               [1.0, 3.0, 4.0])  # first entry of each row


@pytest.mark.parametrize("name", ["w8a", "real-sim", "news"])
def test_ingested_ell_is_lossless(name):
    """Default ELL conversion pads to the max row width — no entry drops."""
    ingest.clear_cache()
    ds = ingest.load(name, split="all")
    assert int(np.asarray(ds.ell.values != 0).sum()) == \
        int((libsvm.parse_file(ingest.fixture_path(name),
                               d=registry.get(name).d)[0].values != 0).sum())


# ---------------------------------------------------------------------------
# cache: gating + integrity
# ---------------------------------------------------------------------------


def test_fetch_without_download_env_raises(isolated_env):
    with pytest.raises(cache.DownloadDisabledError, match="REPRO_ALLOW_DOWNLOAD"):
        cache.fetch("https://example.invalid/blob.bz2")


def test_integrity_mismatch_raises(isolated_env):
    blob = cache.data_dir() / "blobs" / "thing"
    blob.parent.mkdir(parents=True)
    blob.write_text("payload")
    blob.with_name("thing.sha256").write_text("0" * 64 + "\n")
    with pytest.raises(cache.IntegrityError, match="does not match"):
        cache.verify(blob)


def test_trust_on_first_use_records_then_enforces(isolated_env):
    blob = cache.data_dir() / "blobs" / "thing"
    blob.parent.mkdir(parents=True)
    blob.write_text("payload")
    assert cache.verify(blob) == blob           # records the sidecar
    recorded = blob.with_name("thing.sha256").read_text().strip()
    assert recorded == cache.sha256_file(blob)
    blob.write_text("tampered")
    with pytest.raises(cache.IntegrityError):
        cache.verify(blob)


def test_corrupt_cached_full_dataset_fails_loudly(isolated_env):
    meta = registry.get("w8a")
    blob, _ = cache._blob_paths(meta.url)
    blob.parent.mkdir(parents=True)
    blob.write_text("1 1:0.5\n")
    blob.with_name(blob.name + ".sha256").write_text("f" * 64 + "\n")
    with pytest.raises(cache.IntegrityError):
        ingest.load("w8a")


def test_full_blob_preferred_over_fixture_and_changes_hash(isolated_env):
    fixture_hash = None
    # resolve from fixture first (no blob cached yet)
    ingest.clear_cache()
    fixture_hash = ingest.content_hash("w8a")
    # drop a verified full blob into the cache: it wins, hash changes
    meta = registry.get("w8a")
    blob, _ = cache._blob_paths(meta.url)
    blob.parent.mkdir(parents=True, exist_ok=True)
    blob.write_text("".join(f"{(-1) ** i} {1 + i % 300}:0.5\n"
                            for i in range(10)))
    ingest.clear_cache()
    path, kind = ingest.source_path("w8a")
    assert kind == "full" and path == blob
    assert ingest.content_hash("w8a") != fixture_hash
    ds = ingest.load("w8a")
    assert ds.n == 8                            # 80% train split of 10
    assert ds.d == meta.d                       # registry width pins d


# ---------------------------------------------------------------------------
# registry + fixtures vs Table 3
# ---------------------------------------------------------------------------


def test_registry_matches_table3_literals():
    assert set(registry.REAL_DATASETS) == set(TABLE3)
    for name, (n, d, avg_nnz, dense, task) in TABLE3.items():
        meta = registry.get(name)
        assert (meta.n, meta.d, meta.avg_nnz, meta.dense, meta.task) == \
            (n, d, avg_nnz, dense, task)
        assert meta.density == pytest.approx(avg_nnz / d)


@pytest.mark.parametrize("name", sorted(TABLE3))
def test_fixture_resolves_offline_with_table3_profile(name):
    ingest.clear_cache()
    dspec = spec.DatasetSpec(name, source="real")
    prof = dspec.profile()
    ds = dspec.load()
    _, d, avg_nnz, dense, _task = TABLE3[name]
    assert prof.d == d and prof.dense == dense
    assert (prof.n, prof.d, prof.dense) == (ds.n, ds.d, ds.dense)
    # fixture density within 15% of the Table-3 row (split subsampling)
    assert prof.avg_nnz == pytest.approx(avg_nnz, rel=0.15)
    assert set(np.unique(ds.y)) <= {-1.0, 1.0}
    if dense:
        assert ds.X.shape == (ds.n, d)
        assert np.abs(ds.X).max() <= 1.0 + 1e-6    # §6.1 max-abs scaling
    else:
        assert ds.ell.d == d
        assert ds.ell.values.shape[0] == ds.n


def test_train_test_split_disjoint_and_scaled_consistently():
    tr = ingest.load("covtype", split="train")
    te = ingest.load("covtype", split="test")
    al = ingest.load("covtype", split="all")
    assert tr.n + te.n == al.n
    rows_tr = ingest.split_rows(al.n, "train", 0)
    rows_te = ingest.split_rows(al.n, "test", 0)
    assert not set(rows_tr) & set(rows_te)
    # scaling is fit on train: train maxes out at 1, test may exceed it
    assert np.abs(tr.X).max() <= 1.0 + 1e-6
    np.testing.assert_array_equal(rows_tr, ingest.split_rows(al.n, "train", 0))


# ---------------------------------------------------------------------------
# DatasetSpec(source="real") + trial-cache keys
# ---------------------------------------------------------------------------


def test_real_spec_validation():
    with pytest.raises(KeyError, match="unknown real dataset"):
        spec.DatasetSpec("rcv1", source="real")   # no fixture bundled
    with pytest.raises(ValueError, match="shape from the data"):
        spec.DatasetSpec("covtype", source="real", n=8, d=8)
    with pytest.raises(ValueError, match="split only applies"):
        spec.DatasetSpec("covtype", split="train")
    with pytest.raises(ValueError, match="split must be one of"):
        spec.DatasetSpec("covtype", source="real", split="val")


def test_real_and_synthetic_keys_differ_and_round_trip():
    syn = spec.TrialSpec(spec.DatasetSpec("covtype", max_n=128), "lr",
                         sgd.SyncSGD(), 1e-2, 2)
    real = spec.TrialSpec(spec.DatasetSpec("covtype", source="real"), "lr",
                          sgd.SyncSGD(), 1e-2, 2)
    assert syn.key != real.key
    assert "source" not in syn.to_dict()["dataset"]      # legacy key shape
    assert spec.TrialSpec.from_dict(real.to_dict()) == real
    # the persisted spec dict stays constructible (no computed fields) ...
    assert "content_hash" not in real.to_dict()["dataset"]
    # ... while the cache key embeds the ingested content hash
    assert real._key_dict()["dataset"]["content_hash"] == \
        ingest.content_hash("covtype")


def test_trial_key_tracks_fixture_content(tmp_path, monkeypatch):
    trial = spec.TrialSpec(spec.DatasetSpec("skin", source="real"), "lr",
                           sgd.SyncSGD(), 1e-2, 2)
    ingest.clear_cache()
    key_bundled = trial.key
    alt = tmp_path / "fixtures"
    alt.mkdir()
    text = ingest.fixture_path("skin").read_text()
    (alt / "skin.libsvm").write_text(text + "1 1:128 2:4 3:99\n")
    monkeypatch.setenv("REPRO_FIXTURE_DIR", str(alt))
    ingest.clear_cache()
    try:
        assert trial.key != key_bundled         # same spec, new bytes
    finally:
        monkeypatch.delenv("REPRO_FIXTURE_DIR")
        ingest.clear_cache()


def test_runner_caches_real_trials(tmp_path):
    ingest.clear_cache()
    runner = Runner(cache_dir=tmp_path / "cache")
    trial = spec.TrialSpec(spec.DatasetSpec("skin", source="real"), "lr",
                           sgd.SyncSGD(), 1e-2, 3)
    first = runner.run_trial(trial)
    assert not first.cached and len(first.losses) == 4
    again = Runner(cache_dir=tmp_path / "cache").run_trial(trial)
    assert again.cached
    np.testing.assert_allclose(again.losses, first.losses)


def test_runner_runs_sparse_real_dataset_async(tmp_path):
    ingest.clear_cache()
    runner = Runner(cache_dir=tmp_path / "cache")
    trial = spec.TrialSpec(
        spec.DatasetSpec("w8a", source="real"), "svm",
        sgd.AsyncLocalSGD(replicas=4), 1e-2, 3)
    res = runner.run_trial(trial)
    assert np.isfinite(res.losses).all()
    assert res.losses[-1] <= res.losses[0]      # it actually learns
