"""Launch-layer integration: mesh construction + SPMD lowering on forced
host devices (subprocess: the device-count flag must precede jax init)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_mini_mesh_sync_lowering_compiles():
    out = run_py("""
        import jax, jax.numpy as jnp, json
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro import configs
        from repro.launch import specs as S
        from repro.roofline import hlo
        from repro.train import trainer
        from repro.optim.sgd import sgd

        cfg = configs.reduced(configs.get('minitron-4b'), seq_shard=True)
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        p_shapes, p_specs = S.param_shapes_and_specs(cfg)
        b_shapes, b_specs = S.batch_specs(cfg, 'train', 16, 8)
        opt = sgd(1e-2)
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_specs = trainer.opt_state_specs(o_shapes, p_specs)
        step = trainer.make_sync_step(cfg, mesh, opt, p_specs)
        sh = lambda s: trainer.resolve_tree(s, mesh, cfg)
        with mesh:
            lowered = jax.jit(step,
                in_shardings=(sh(p_specs), sh(o_specs), sh(b_specs)),
                out_shardings=(sh(p_specs), sh(o_specs),
                               NamedSharding(mesh, P()))).lower(
                p_shapes, o_shapes, b_shapes)
            compiled = lowered.compile()
        ca = hlo.cost_analysis_dict(compiled)
        print(json.dumps({'flops': ca.get('flops', -1),
                          'ok': True}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["ok"] and res["flops"] > 0


def test_mini_mesh_decode_lowering_compiles():
    out = run_py("""
        import jax, jax.numpy as jnp, json
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro import configs
        from repro.launch import specs as S
        from repro.train import trainer

        cfg = configs.reduced(configs.get('zamba2-1.2b'))
        mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        p_shapes, p_specs = S.param_shapes_and_specs(cfg)
        c_shapes, c_specs = S.cache_shapes_and_specs(cfg, 8, 32)
        b_shapes, b_specs = S.batch_specs(cfg, 'decode', 32, 8)
        step = trainer.make_decode_step(cfg, mesh)
        sh = lambda s: trainer.resolve_tree(s, mesh, cfg)
        with mesh:
            compiled = jax.jit(step,
                in_shardings=(sh(p_specs), sh(c_specs), sh(b_specs),
                              NamedSharding(mesh, P())),
                out_shardings=(NamedSharding(mesh, P()), sh(c_specs))
                ).lower(p_shapes, c_shapes, b_shapes,
                        jax.ShapeDtypeStruct((), jnp.int32)).compile()
        print(json.dumps({'ok': True,
                          'mem': compiled.memory_analysis() is not None}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["ok"]


def test_production_mesh_shapes():
    out = run_py("""
        import jax, json
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        print(json.dumps({'single': dict(m1.shape), 'multi': dict(m2.shape)}))
    """, devices=512)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["single"] == {"data": 16, "model": 16}
    assert res["multi"] == {"pod": 2, "data": 16, "model": 16}
