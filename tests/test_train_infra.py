"""Trainer, checkpointing, fault tolerance, compression, pipeline, serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.checkpoint import (CheckpointManager, load_checkpoint,
                              save_checkpoint, latest_step)
from repro.data.pipeline import TokenPipeline, shard_with_halo
from repro.nn import transformer
from repro.optim import compress
from repro.optim.sgd import sgd as make_sgd, sgd_momentum, apply_updates
from repro.optim.adam import adam as make_adam
from repro.train import fault, trainer


# ---------------------------------------------------------------------------
# resolve_spec
# ---------------------------------------------------------------------------


def test_resolve_spec_virtual_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = configs.reduced(configs.get("minitron-4b"), seq_shard=True)
    s = trainer.resolve_spec(P("batch", "seq", None), mesh, cfg)
    assert s == P(("data",), "model", None)
    cfg2 = configs.reduced(configs.get("minitron-4b"), seq_shard=False)
    s2 = trainer.resolve_spec(P("batch", "seq", None), mesh, cfg2)
    assert s2 == P(("data",), None, None)
    # pod axis dropped when absent from the mesh
    s3 = trainer.resolve_spec(P("pod", "model"), mesh, cfg)
    assert s3 == P(None, "model")
    # extra mapping overrides (the long_500k fallback)
    s4 = trainer.resolve_spec(P("batch", None, "kvseq", None), mesh, cfg,
                              extra={"batch": (), "kvseq": ("data", "model")})
    assert s4 == P(None, None, ("data", "model"), None)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def quad_problem():
    w0 = {"a": jnp.asarray([3.0, -2.0]), "b": jnp.asarray([[1.5]])}
    grad_fn = jax.grad(lambda w: sum(jnp.sum(jnp.square(x))
                                     for x in jax.tree.leaves(w)))
    return w0, grad_fn


@pytest.mark.parametrize("opt_fn", [lambda: make_sgd(0.1),
                                    lambda: sgd_momentum(0.05),
                                    lambda: make_adam(0.1)])
def test_optimizers_minimize_quadratic(opt_fn):
    w, grad_fn = quad_problem()
    opt = opt_fn()
    state = opt.init(w)
    for _ in range(100):
        u, state = opt.update(grad_fn(w), state, w)
        w = apply_updates(w, u)
    norm = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(w))
    assert norm < 0.05


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


def test_quantize_error_bounded(rng):
    x = jnp.asarray(rng.normal(0, 3, (1000,)).astype(np.float32))
    q, s = compress.quantize_leaf(x)
    deq = compress.dequantize_leaf(q, s, x)
    blocks = np.asarray(x).copy()
    err = np.abs(np.asarray(deq) - np.asarray(x))
    # error bounded by half a quantization step per block
    scale_per_elem = np.repeat(np.asarray(s).reshape(-1),
                               compress.BLOCK)[:1000]
    assert np.all(err <= 0.5 * scale_per_elem + 1e-7)


def test_error_feedback_reduces_bias(rng):
    """With EF, the *sum* of dequantized values tracks the true sum."""
    tree = {"w": jnp.asarray(rng.normal(0, 1, (512,)).astype(np.float32))}
    ef = None
    total_deq = np.zeros(512, np.float32)
    for _ in range(20):
        qt, ef = compress.compress_tree(tree, ef)
        total_deq += np.asarray(compress.decompress_tree(qt, tree)["w"])
    true_total = 20 * np.asarray(tree["w"])
    # residual carried in ef: |sum error| stays bounded (not growing with t)
    assert np.max(np.abs(total_deq - true_total)) <= \
        np.max(np.abs(np.asarray(ef["w"]))) + 1e-4


def test_compression_ratio():
    tree = {"w": jnp.zeros((4096,), jnp.float32)}
    assert compress.compression_ratio(tree) > 3.0


def test_compress_tree_single_pass_per_leaf(monkeypatch, rng):
    """compress_tree used to evaluate its per-leaf closure three times
    (one jax.tree.map per output tree); it must quantize each leaf once."""
    calls = {"n": 0}
    real = compress.quantize_leaf

    def counting(x):
        calls["n"] += 1
        return real(x)

    monkeypatch.setattr(compress, "quantize_leaf", counting)
    tree = {"a": jnp.asarray(rng.normal(0, 1, (300,)).astype(np.float32)),
            "b": {"c": jnp.asarray(rng.normal(0, 1, (5, 7)).astype(np.float32))}}
    qt, ef = compress.compress_tree(tree)
    assert calls["n"] == 2                       # exactly one pass per leaf
    # output trees keep the input structure; round-trip error is bounded
    assert jax.tree.structure(qt["q"]) == jax.tree.structure(tree)
    assert jax.tree.structure(qt["s"]) == jax.tree.structure(tree)
    assert jax.tree.structure(ef) == jax.tree.structure(tree)
    deq = compress.decompress_tree(qt, tree)
    for x, r, e in zip(jax.tree.leaves(tree), jax.tree.leaves(deq),
                       jax.tree.leaves(ef)):
        np.testing.assert_allclose(np.asarray(x) - np.asarray(r),
                                   np.asarray(e).reshape(x.shape),
                                   rtol=1e-5, atol=1e-6)


def test_compress_tree_error_feedback_unbiased_over_steps(rng):
    """Repeated compression of the same tree with persistent error
    feedback: the cumulative dequantized sum tracks the true sum (the
    residual never compounds), i.e. the quantizer is unbiased over time."""
    x = rng.normal(0, 1, (384,)).astype(np.float32)
    tree = {"w": jnp.asarray(x)}
    ef = None
    total = np.zeros_like(x)
    for t in range(1, 31):
        qt, ef = compress.compress_tree(tree, ef)
        total += np.asarray(compress.decompress_tree(qt, tree)["w"])
        # bias after t rounds is exactly the residual carried in ef
        np.testing.assert_allclose(t * x - total, np.asarray(ef["w"]),
                                   rtol=1e-4, atol=1e-4)
    scale = np.max(np.abs(x)) / 127.0
    assert np.max(np.abs(30 * x - total)) <= scale + 1e-5


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {"a": jnp.asarray(rng.normal(0, 1, (8, 4)).astype(np.float32)),
            "b": {"c": jnp.asarray(rng.normal(0, 1, (3,)), dtype=jnp.bfloat16),
                  "d": jnp.asarray([7], jnp.int32)}}
    save_checkpoint(tmp_path, 5, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = load_checkpoint(tmp_path, like)
    assert step == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_checkpoint_keep_k(tmp_path):
    tree = {"x": jnp.zeros(3)}
    for s in range(6):
        save_checkpoint(tmp_path, s, tree, keep=2)
    assert latest_step(tmp_path) == 5
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2


def test_checkpoint_manager_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, every=2)
    tree = {"x": jnp.arange(4.0)}
    assert not mgr.maybe_save(1, tree)
    assert mgr.maybe_save(2, tree)
    mgr.wait()
    assert latest_step(tmp_path) == 2


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_resilient_loop_restart(tmp_path):
    """Inject a failure; the loop restores the checkpoint and completes."""
    def step(state, batch):
        return state + batch, {"v": float(state)}

    ckpt = CheckpointManager(tmp_path, every=2)
    fired = {"done": False}

    def failure(s):
        if s == 5 and not fired["done"]:
            fired["done"] = True
            return True
        return False

    loop = fault.ResilientLoop(step, ckpt, jnp.zeros(()), resume=False,
                               failure_hook=failure)
    ones = iter(lambda: jnp.ones(()), None)
    state, history = loop.run(ones, 8)
    kinds = [h[0] for h in history]
    assert "restart" in kinds
    assert kinds.count("step") >= 6


def test_heartbeat_and_merge_gate():
    hb = fault.Heartbeat(4, timeout_s=1e-3)
    import time
    time.sleep(0.01)
    assert not hb.alive().any()
    hb.beat(2)
    assert hb.alive()[2] and not hb.alive()[0]
    gate = fault.MergeGate(4, hb)
    assert gate.should_merge(4) and not gate.should_merge(3)


def test_heartbeat_injectable_clock_is_deterministic():
    """Staleness driven by an injected clock — no sleeping, no wall time."""
    now = [0.0]
    hb = fault.Heartbeat(3, timeout_s=5.0, clock=lambda: now[0])
    assert hb.alive().all()                     # all seen at t=0
    now[0] = 4.99
    assert hb.alive().all()
    now[0] = 5.0
    assert not hb.alive().any()                 # timeout is exclusive
    hb.beat(1)
    assert list(hb.alive()) == [False, True, False]
    gate = fault.MergeGate(2, hb)
    np.testing.assert_array_equal(gate.alive_mask(), hb.alive())
    now[0] = 10.1
    assert not gate.alive_mask().any()


def test_elastic_rescale_identity():
    state = {"w": jnp.arange(8.0)}
    dev = jax.devices()[0]
    shard = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev),
                         state)
    out = fault.elastic_rescale(state, shard)
    np.testing.assert_allclose(out["w"], state["w"])


# ---------------------------------------------------------------------------
# pipeline
# ---------------------------------------------------------------------------


def test_shard_with_halo_properties():
    shards = shard_with_halo(100, 4, rep_k=5)
    assert all(len(s) == 30 for s in shards)
    base = np.concatenate([s[:25] for s in shards])
    assert sorted(base.tolist()) == list(range(100))
    np.testing.assert_array_equal(shards[0][-5:], np.arange(25, 30))
    np.testing.assert_array_equal(shards[3][-5:], np.arange(0, 5))


def test_token_pipeline_shapes():
    pipe = TokenPipeline(vocab=100, seq=16, global_batch=4)
    batch = next(iter(pipe))
    assert batch["tokens"].shape == (4, 16)
    assert batch["labels"].shape == (4, 16)
    assert int(batch["tokens"].max()) < 100


# ---------------------------------------------------------------------------
# async-local training semantics
# ---------------------------------------------------------------------------


def test_async_local_merge_preserves_replica_mean(rng):
    cfg = configs.reduced(configs.get("minitron-4b"))
    opt = make_sgd(0.1)
    params, specs = transformer.init_params(cfg, jax.random.PRNGKey(0))
    local, merge = trainer.make_async_local_step(cfg, None, opt, specs)
    R = 2
    stacked = jax.tree.map(
        lambda x: jnp.stack([x, x + 0.01 * jnp.ones_like(x)]), params)
    merged, _, _ = merge(stacked)
    for m, s in zip(jax.tree.leaves(merged), jax.tree.leaves(stacked)):
        np.testing.assert_allclose(np.asarray(m[0], np.float32),
                                   np.asarray(s, np.float32).mean(0),
                                   rtol=1e-2, atol=1e-4)
        np.testing.assert_allclose(np.asarray(m[0], np.float32),
                                   np.asarray(m[1], np.float32))


def test_train_driver_sync_and_async(tmp_path):
    from repro.launch import train as train_cli
    losses = train_cli.main(["--arch", "h2o-danube-1.8b", "--smoke",
                             "--steps", "8", "--lr", "0.3",
                             "--ckpt-dir", str(tmp_path / "s")])
    assert losses[-1] < losses[0]
    losses = train_cli.main(["--arch", "h2o-danube-1.8b", "--smoke",
                             "--steps", "8", "--lr", "0.3",
                             "--update", "async", "--merge-every", "2",
                             "--ckpt-dir", str(tmp_path / "a")])
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------


def test_serve_engine_batched_requests():
    from repro.serve.engine import ServeEngine, Request
    cfg = configs.reduced(configs.get("minitron-4b"))
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    reqs = [Request(i, np.asarray([1 + i, 2, 3]), max_new=5)
            for i in range(4)]
    done = eng.run(reqs, max_ticks=100)
    assert len(done) == 4
    assert all(len(r.out) >= 5 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out)


def test_compressed_merge_tracks_mean(rng):
    """int8+error-feedback cross-pod merge: merged params track the true
    replica mean within one quantization step, and repeated merges do not
    accumulate bias (error feedback)."""
    cfg = configs.reduced(configs.get("minitron-4b"))
    opt = make_sgd(0.1)
    params, specs = transformer.init_params(cfg, jax.random.PRNGKey(0))
    _, merge = trainer.make_async_local_step(cfg, None, opt, specs,
                                             compress_merge=True)
    anchor = params
    ef = None
    drift = jax.tree.map(
        lambda x: jnp.asarray(rng.normal(0, 0.01, x.shape), x.dtype), params)
    stacked = jax.tree.map(
        lambda x, d: jnp.stack([x + d, x - d]), params, drift)
    for _ in range(3):
        merged, anchor, ef = merge(stacked, anchor, ef)
        # replicas re-synchronized
        for m in jax.tree.leaves(merged):
            np.testing.assert_allclose(np.asarray(m[0], np.float32),
                                       np.asarray(m[1], np.float32))
        stacked = merged
    # after merging, params ~= original mean (= params): quantization error
    # bounded by block scale, no systematic bias
    for m, p0 in zip(jax.tree.leaves(merged), jax.tree.leaves(params)):
        err = np.abs(np.asarray(m[0], np.float32) - np.asarray(p0, np.float32))
        assert err.max() < 0.02, err.max()


def test_compression_halves_merge_bytes():
    from repro.optim import compress
    tree = {"w": jnp.zeros((1 << 16,), jnp.bfloat16)}
    # bf16 -> int8 + fp32 scales per 256-block: ratio just under 2x for bf16
    assert compress.compression_ratio(tree) > 1.9
