"""Core GLM math: gradient paths agree, epochs match semantics, SGD converges."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import glm, sgd, convergence
from repro.data import synthetic


@pytest.fixture(scope="module")
def dense_ds():
    return synthetic.make_dense("toy", 512, 24, seed=0)


@pytest.fixture(scope="module")
def problem(dense_ds):
    return glm.GLMProblem("lr", jnp.asarray(dense_ds.X),
                          jnp.asarray(dense_ds.y), 1e-3)


@pytest.mark.parametrize("task", ["lr", "svm"])
def test_grad_paths_agree(task, dense_ds, rng):
    X = jnp.asarray(dense_ds.X)
    y = jnp.asarray(dense_ds.y)
    w = jnp.asarray(rng.normal(0, 0.1, X.shape[1]).astype(np.float32))
    g_comp = glm.grad_primitive_composition(task, w, X, y)
    g_fused = glm.grad_fused(task, w, X, y)
    np.testing.assert_allclose(g_comp, g_fused, rtol=1e-4, atol=1e-4)


def test_lr_grad_matches_autodiff(dense_ds, rng):
    X = jnp.asarray(dense_ds.X)
    y = jnp.asarray(dense_ds.y)
    w = jnp.asarray(rng.normal(0, 0.1, X.shape[1]).astype(np.float32))
    g = glm.grad_fused("lr", w, X, y)
    g_auto = jax.grad(glm.lr_loss)(w, X, y)
    np.testing.assert_allclose(g, g_auto, rtol=1e-3, atol=1e-3)


def test_incremental_epoch_matches_manual(dense_ds):
    """scan-based incremental epoch == explicit python loop (8 examples)."""
    X = jnp.asarray(dense_ds.X[:8])
    y = jnp.asarray(dense_ds.y[:8])
    w = jnp.zeros(X.shape[1])
    w_scan = glm.incremental_epoch("lr", w, X, y, 0.1)
    w_ref = np.zeros(X.shape[1], np.float32)
    for i in range(8):
        m = y[i] * (X[i] @ w_ref)
        pull = -y[i] * (1.0 / (1.0 + np.exp(m)))
        w_ref = w_ref - 0.1 * pull * np.asarray(X[i])
    np.testing.assert_allclose(w_scan, w_ref, rtol=1e-4, atol=1e-5)


def test_minibatch_b1_equals_incremental(dense_ds):
    X = jnp.asarray(dense_ds.X[:32])
    y = jnp.asarray(dense_ds.y[:32])
    w = jnp.zeros(X.shape[1])
    a = glm.incremental_epoch("svm", w, X, y, 0.05)
    b = glm.minibatch_epoch("svm", w, X, y, 0.05, 1)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_sync_sgd_converges(problem):
    res = sgd.run(problem, sgd.SyncSGD(), 30)
    assert res.losses[-1] < 0.7 * res.losses[0]
    assert np.all(np.isfinite(res.losses))


def test_async_local_sgd_converges(problem):
    res = sgd.run(problem._replace(step=0.1),
                  sgd.AsyncLocalSGD(replicas=4, local_batch=8), 15)
    assert res.losses[-1] < 0.7 * res.losses[0]


def test_sync_statistical_efficiency_is_batch_gd(problem):
    """Paper Section 4: synchronous SGD == batch GD semantics, independent
    of 'device' — same losses as the straight batch-GD recurrence."""
    res = sgd.run(problem, sgd.SyncSGD(), 5)
    w = jnp.zeros(problem.X.shape[1])
    expected = [float(glm.lr_loss(w, problem.X, problem.y))]
    for _ in range(5):
        w = w - problem.step * glm.grad_fused("lr", w, problem.X, problem.y)
        expected.append(float(glm.lr_loss(w, problem.X, problem.y)))
    np.testing.assert_allclose(res.losses, expected, rtol=1e-3)


def test_time_to_convergence_accounting():
    losses = np.array([10.0, 5.0, 2.0, 1.0, 0.5])
    times = np.array([1.0, 1.0, 1.0, 1.0])
    r = sgd.RunResult(losses, times, "x", "lr")
    assert r.epochs_to(2.0) == 2
    assert r.time_to(2.0) == 2.0
    assert r.epochs_to(0.1) is None and r.time_to(0.1) is None


def test_step_size_grid_search(dense_ds):
    X, y = jnp.asarray(dense_ds.X), jnp.asarray(dense_ds.y)

    def mk(step):
        return glm.GLMProblem("lr", X, y, step)

    res0 = sgd.run(mk(1e-3), sgd.SyncSGD(), 25)
    target = float(res0.losses.min())
    gs = convergence.grid_search_step(
        mk, sgd.SyncSGD(), 10, target * 1.1, steps=[1e-5, 1e-3, 1e-1])
    assert gs.best_step in (1e-5, 1e-3, 1e-1)
    # the absurdly large step should not win
    assert gs.best_step != 1e-1 or np.isfinite(
        gs.all_results[1e-1].losses[-1])
