"""Shared fixtures: seeded RNG and kernel test-data factories.

The factories are used by both the per-kernel shape sweeps
(test_kernels.py) and the backend conformance harness
(test_kernel_conformance.py), so every suite exercises identically
distributed inputs.
"""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def glm_data(rng):
    """Factory: (n, d[, dtype]) -> (X [n,d], y [n] in {-1,+1}, w [d])."""
    import jax.numpy as jnp

    def make(n, d, dtype=np.float32):
        X = jnp.asarray(rng.normal(0, 1, (n, d)), dtype=dtype)
        y = jnp.asarray(np.where(rng.random(n) < 0.5, -1.0, 1.0), dtype=dtype)
        w = jnp.asarray(rng.normal(0, 0.1, d), dtype=dtype)
        return X, y, w

    return make


@pytest.fixture
def attn_data(rng):
    """Factory: (b, hq, hkv, sq, sk, hd[, dtype]) -> (q, k, v)."""
    import jax.numpy as jnp

    def make(b, hq, hkv, sq, sk, hd, dtype=np.float32):
        q = jnp.asarray(rng.normal(0, 1, (b, hq, sq, hd)), dtype=dtype)
        k = jnp.asarray(rng.normal(0, 1, (b, hkv, sk, hd)), dtype=dtype)
        v = jnp.asarray(rng.normal(0, 1, (b, hkv, sk, hd)), dtype=dtype)
        return q, k, v

    return make


@pytest.fixture
def ell_data(rng):
    """Factory: (n, d, k[, dtype]) -> (values, indices, y, w) in ELL form."""
    import jax.numpy as jnp
    from repro.data import synthetic

    def make(n, d, k, dtype=np.float32):
        ds = synthetic.make_sparse("conf", n, d, k * 0.6, k, seed=int(d))
        values = jnp.asarray(ds.ell.values, dtype=dtype)
        indices = jnp.asarray(ds.ell.indices)
        y = jnp.asarray(ds.y, dtype=dtype)
        w = jnp.asarray(rng.normal(0, 0.1, d), dtype=dtype)
        return values, indices, y, w

    return make
