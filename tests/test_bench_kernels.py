"""Tests for the kernel trajectory producer, its store, and the gate."""
import json

import pytest

from repro.roofline import kernels as rkernels
from repro.study import claims
from repro.study.store import KernelBenchStore


# ---------------------------------------------------------------------------
# claims.check_bench_kernels: conformance + regression gate
# ---------------------------------------------------------------------------


def _row(label="glm_grad/x/float32/default", wall=1.0, match=True,
         baseline=None):
    return {"label": label, "wall_s": wall, "pallas_match": match,
            "baseline_wall_s": baseline}


def test_gate_clean_rows_pass():
    assert claims.check_bench_kernels([_row(), _row(match=None)]) == []


def test_gate_flags_oracle_mismatch():
    bad = claims.check_bench_kernels([_row(match=False)])
    assert len(bad) == 1 and "mismatch" in bad[0]


def test_gate_flags_regression_over_tolerance():
    tol = claims.KERNEL_REGRESSION_TOL
    ok = _row(wall=1.0 * (1 + tol) * 0.99, baseline=1.0)
    slow = _row(wall=1.0 * (1 + tol) * 1.05, baseline=1.0)
    assert claims.check_bench_kernels([ok]) == []
    bad = claims.check_bench_kernels([slow])
    assert len(bad) == 1 and "regressed" in bad[0]


def test_gate_ignores_missing_baseline():
    # cross-host / first-run points have no comparable committed entry
    assert claims.check_bench_kernels([_row(wall=100.0, baseline=None)]) == []


def test_gate_rejects_fully_unchecked_run():
    """Regression for the vacuous ``all({})`` bug: a run where no Pallas
    flavor was checked must not validate as green."""
    rows = [_row(match=None), _row(label="b", match=None)]
    bad = claims.check_bench_kernels(rows)
    assert len(bad) == 1 and "unchecked" in bad[0]
    # one checked row is enough to clear the blanket violation
    assert claims.check_bench_kernels(rows[:1] + [_row()]) == []


# ---------------------------------------------------------------------------
# KernelBenchStore determinism
# ---------------------------------------------------------------------------


def test_kernel_store_snapshot_sorted_and_deterministic(tmp_path):
    s = KernelBenchStore(tmp_path / "BENCH_kernels.json",
                         jsonl_path=tmp_path / "runs.jsonl")
    s.record_entry("b/label", {"wall_s": 2.0})
    s.record_entry("a/label", {"wall_s": 1.0}, cached=True)
    snap = s.snapshot()
    assert list(snap["entries"]) == ["a/label", "b/label"]
    assert "ts" not in json.dumps(snap)
    p = s.write()
    first = p.read_bytes()
    s.write()
    assert p.read_bytes() == first  # snapshot has no run-varying fields
    assert KernelBenchStore.load(p) == snap
    # run-variance goes to the sidecar only
    lines = [json.loads(l) for l in (tmp_path / "runs.jsonl").open()]
    assert len(lines) == 2 and all("ts" in l for l in lines)
    assert lines[0]["n_entries"] == 2 and lines[0]["n_cached"] == 1


# ---------------------------------------------------------------------------
# Analytic roofline annotations
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kernel,info", [
    ("glm_grad", {"n": 512, "d": 128}),
    ("glm_sgd", {"n": 256, "d": 64}),
    ("glm_sparse", {"n": 256, "d": 512, "k": 8}),
    ("glm_sgd_sparse", {"n": 128, "d": 256, "k": 8}),
    ("glm_score", {"n": 32, "d": 512, "k": 8}),
    ("flash_attn", {"batch": 1, "heads_q": 2, "heads_kv": 1,
                    "seq_q": 64, "seq_k": 64, "head_dim": 32}),
])
def test_roofline_annotation_fields(kernel, info):
    a = rkernels.annotate(kernel, info, wall_s=1e-3)
    assert a["flops"] > 0 and a["hbm_bytes"] > 0
    assert a["bound"] in ("compute", "memory")
    assert a["tpu_bound_s"] == max(a["tpu_compute_s"], a["tpu_memory_s"])
    assert a["achieved_gflops"] == pytest.approx(a["flops"] / 1e-3 / 1e9)
    # without a measurement the derived fields are absent, not zero
    assert "achieved_gflops" not in rkernels.annotate(kernel, info)


def test_roofline_unknown_kernel_raises():
    with pytest.raises(KeyError):
        rkernels.kernel_cost("nope", {})


def test_roofline_intensity_orders_families():
    """Dense GLM gradient has ~matmul intensity; the sparse families are
    gather-bound and must price below it."""
    dense = rkernels.kernel_cost("glm_grad", {"n": 1024, "d": 512})
    sp = rkernels.kernel_cost("glm_sparse", {"n": 1024, "d": 512, "k": 8})
    assert (dense["flops"] / dense["hbm_bytes"]
            > sp["flops"] / sp["hbm_bytes"])


# ---------------------------------------------------------------------------
# Producer end-to-end (micro shapes): trajectory points + reproducibility
# ---------------------------------------------------------------------------


TINY_SHAPES = {
    "glm_grad": {"ci": dict(n=32, d=16)},
    "glm_sgd": {"ci": dict(n=16, d=8)},
    "glm_sparse": {"ci": dict(n=16, d=128, k=4)},
    "glm_sgd_sparse": {"ci": dict(n=16, d=64, k=4)},
    "flash_attn": {"ci": dict(batch=1, heads_q=2, heads_kv=1, seq_q=16,
                              seq_k=16, head_dim=8)},
}


def test_producer_trajectory_and_byte_reproducibility(tmp_path, monkeypatch):
    from benchmarks import bench_kernels, common

    monkeypatch.setattr(bench_kernels, "SHAPES", TINY_SHAPES)
    monkeypatch.setattr(common, "RESULTS_DIR", tmp_path / "res")
    out = tmp_path / "BENCH_kernels.json"

    rows = bench_kernels.run("ci", out_json=str(out))
    data = json.loads(out.read_text())
    kernels_seen = {e["kernel"] for e in data["entries"].values()}
    assert kernels_seen == set(TINY_SHAPES)  # >=1 point per family
    for e in data["entries"].values():
        assert e["wall_s"] > 0
        assert e["pallas_match"] is True  # interpret flavor checked on CPU
        assert e["roofline"]["bound"] in ("compute", "memory")
        assert {"host", "device_kind", "backend", "config"} <= set(e)
    # tuned + bf16 variants present for every family
    variants = {(e["kernel"], e["dtype"], e["variant"])
                for e in data["entries"].values()}
    for k in TINY_SHAPES:
        assert (k, "float32", "tuned") in variants
        assert (k, "bfloat16", "default") in variants
    # cold run: committed file absent -> no baselines, gate clean
    assert all(r["baseline_wall_s"] is None for r in rows)
    assert claims.check_bench_kernels(rows) == []

    first = out.read_bytes()
    rows2 = bench_kernels.run("ci", out_json=str(out))
    assert out.read_bytes() == first  # warm re-run is byte-identical
    # warm run gates against the (now committed) same-host trajectory
    assert all(r["baseline_wall_s"] == r["wall_s"] for r in rows2)
    assert claims.check_bench_kernels(rows2) == []
