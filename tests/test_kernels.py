"""Per-kernel allclose vs ref.py oracles — shape/dtype sweeps (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic
from repro.kernels import common
from repro.kernels.glm_grad import glm_grad
from repro.kernels.glm_grad.ref import glm_grad_ref
from repro.kernels.glm_sgd import glm_sgd_epoch
from repro.kernels.glm_sgd.ref import glm_sgd_epoch_ref
from repro.kernels.glm_sgd_sparse import ell_sgd_epoch
from repro.kernels.glm_sgd_sparse.ref import ell_sgd_epoch_ref
from repro.kernels.glm_sparse import ell_glm_grad
from repro.kernels.glm_sparse.ref import ell_glm_grad_ref
from repro.kernels.flash_attn import flash_attention
from repro.kernels.flash_attn.ref import attention_ref


# ---------------------------------------------------------------------------
# pick_block: the block it returns must always be aligned (regression: it
# used to fall back to ``size`` itself — e.g. pick_block(6, 128, 8) == 6 —
# handing Pallas a sublane-misaligned block)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("size,preferred,multiple,want", [
    (128, 128, 8, 128),   # preferred fits exactly
    (96, 128, 8, 96),     # aligned divisor <= preferred
    (64, 16, 8, 16),      # largest aligned divisor under preferred
    (200, 128, 8, 40),    # 200 = 8*25: biggest aligned divisor <= 128 is 40
    (8, 128, 8, 8),       # minimum aligned size
    (256, 128, 128, 128),  # lane-multiple constraint
])
def test_pick_block_returns_aligned_divisor(size, preferred, multiple, want):
    got = common.pick_block(size, preferred, multiple)
    assert got == want
    assert size % got == 0 and got % multiple == 0


@pytest.mark.parametrize("size", [6, 7, 13, 31, 127])  # odd / prime extents
def test_pick_block_rejects_unalignable_sizes(size):
    with pytest.raises(ValueError, match="not itself a multiple"):
        common.pick_block(size, 128, 8)


def test_pick_block_whole_extent_fallback_stays_aligned():
    # no aligned divisor <= preferred, but the extent itself is aligned:
    # one whole-extent block is the only correct answer
    assert common.pick_block(40, 4, 8) == 40


@pytest.mark.parametrize("task", ["lr", "svm"])
@pytest.mark.parametrize("layout", ["row", "col"])
@pytest.mark.parametrize("n,d", [(64, 54), (200, 16), (96, 300), (32, 128)])
def test_glm_grad_kernel(task, layout, n, d, glm_data):
    X, y, w = glm_data(n, d)
    ref = glm_grad_ref(task, w, X, y)
    out = glm_grad(task, w, X, y, layout=layout, block_rows=16)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=2e-3)


@pytest.mark.parametrize("task", ["lr", "svm"])
@pytest.mark.parametrize("mb", [1, 4, 16])
@pytest.mark.parametrize("n,d", [(32, 54), (64, 130)])
def test_glm_sgd_kernel(task, mb, n, d, glm_data):
    X, y, w = glm_data(n, d)
    ref = glm_sgd_epoch_ref(task, w, X, y, 0.02, mb)
    out = glm_sgd_epoch(task, w, X, y, step=0.02, micro_batch=mb)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("task", ["lr", "svm"])
@pytest.mark.parametrize("n,d,k", [(64, 512, 12), (100, 700, 20), (40, 256, 6)])
def test_glm_sparse_kernel(task, n, d, k, rng):
    ds = synthetic.make_sparse("sp", n, d, k * 0.6, k, seed=int(d))
    y = jnp.asarray(ds.y)
    w = jnp.asarray(rng.normal(0, 0.1, d).astype(np.float32))
    ref = ell_glm_grad_ref(task, w, ds.ell.values, ds.ell.indices, y)
    out = ell_glm_grad(task, w, ds.ell.values, ds.ell.indices, y,
                       block_rows=8, d_block=256, force_path="pallas")
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=2e-3)


@pytest.mark.parametrize("task", ["lr", "svm"])
@pytest.mark.parametrize("mb", [1, 4, 16])
@pytest.mark.parametrize("n,d,k", [(32, 200, 6), (64, 130, 10)])
def test_ell_sgd_kernel(task, mb, n, d, k, rng):
    ds = synthetic.make_sparse("sp-sgd", n, d, k * 0.6, k, seed=int(d))
    y = jnp.asarray(ds.y)
    w = jnp.asarray(rng.normal(0, 0.1, d).astype(np.float32))
    ref = ell_sgd_epoch_ref(task, w, ds.ell.values, ds.ell.indices, y,
                            0.05, mb)
    out = ell_sgd_epoch(task, w, ds.ell.values, ds.ell.indices, y,
                        step=0.05, micro_batch=mb)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=2e-3)


def test_glm_sparse_auto_path_picks_xla_when_huge(rng):
    """Very wide models route to the XLA gather path automatically."""
    from repro.kernels.glm_sparse.ops import pallas_path_ok
    assert not pallas_path_ok(n=10_000, d=1_000_000)
    assert pallas_path_ok(n=10_000, d=20_958)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
def test_flash_attention_kernel(causal, window, hq, hkv, rng):
    B, S, hd = 2, 64, 32
    q = jnp.asarray(rng.normal(0, 1, (B, hq, S, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (B, hkv, S, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (B, hkv, S, hd)).astype(np.float32))
    kr = jnp.repeat(k, hq // hkv, axis=1)
    vr = jnp.repeat(v, hq // hkv, axis=1)
    ref = attention_ref(q, kr, vr, causal=causal, window=window)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=16, block_k=16)
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=2e-3)


def test_flash_attention_bf16():
    rng = np.random.default_rng(7)
    B, H, S, hd = 1, 2, 32, 16
    q = jnp.asarray(rng.normal(0, 1, (B, H, S, hd)), dtype=jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (B, H, S, hd)), dtype=jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (B, H, S, hd)), dtype=jnp.bfloat16)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=8, block_k=8)
    np.testing.assert_allclose(out.astype(jnp.float32), ref, rtol=0.05,
                               atol=0.05)


def test_flash_attention_decode_shape():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(0, 1, (2, 4, 1, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (2, 2, 64, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (2, 2, 64, 16)).astype(np.float32))
    ref = attention_ref(q, jnp.repeat(k, 2, 1), jnp.repeat(v, 2, 1),
                        causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=1, block_k=16)
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)
