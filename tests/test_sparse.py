"""ELL sparse format + sparse GLM math."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import glm, sparse, sgd
from repro.data import synthetic


@pytest.fixture(scope="module")
def sp_ds():
    return synthetic.make_sparse("sp", 256, 128, 8.0, 24, seed=1)


def test_ell_roundtrip(rng):
    X = rng.normal(0, 1, (16, 32)).astype(np.float32)
    X[rng.random((16, 32)) < 0.7] = 0.0
    m = sparse.from_dense(X)
    np.testing.assert_allclose(sparse.to_dense(m), X, atol=1e-6)


def test_sparse_grad_equals_dense(sp_ds, rng):
    y = jnp.asarray(sp_ds.y)
    w = jnp.asarray(rng.normal(0, 0.1, sp_ds.d).astype(np.float32))
    Xd = sparse.to_dense(sp_ds.ell)
    for task in ("lr", "svm"):
        gs = sparse.grad(task, sp_ds.ell, y, w)
        gd = glm.grad_fused(task, w, Xd, y)
        np.testing.assert_allclose(gs, gd, rtol=1e-3, atol=1e-3)


def test_sparse_incremental_equals_dense(sp_ds):
    y = jnp.asarray(sp_ds.y[:32])
    ell32 = sparse.ELLMatrix(sp_ds.ell.values[:32], sp_ds.ell.indices[:32],
                             sp_ds.d)
    Xd = sparse.to_dense(ell32)
    w0 = jnp.zeros(sp_ds.d)
    ws = sparse.incremental_epoch("lr", w0, ell32, y, 0.05)
    wd = glm.incremental_epoch("lr", w0, Xd, y, 0.05)
    np.testing.assert_allclose(ws, wd, rtol=1e-3, atol=1e-4)


def test_sparse_async_sgd_converges(sp_ds):
    y = jnp.asarray(sp_ds.y)
    prob = ("lr", sp_ds.ell, y, 0.05)
    res = sgd.run(prob, sgd.AsyncLocalSGD(replicas=4, local_batch=4), 10,
                  sparse_data=True)
    assert res.losses[-1] < res.losses[0]
    assert np.all(np.isfinite(res.losses))
