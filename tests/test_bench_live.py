"""Tests for the live trajectory producer, its store, and the gate."""
import json

from repro.study import claims
from repro.study.store import LiveBenchStore


# ---------------------------------------------------------------------------
# claims.check_bench_live: convergence + consistency + regression gate
# ---------------------------------------------------------------------------


def _conv(label="live/lr/d256/r4-m4", losses=(10.0, 5.0), wall=1.0,
          sps=50.0, baseline=None):
    return {"label": label, "kind": "convergence", "losses": list(losses),
            "wall_s": wall, "steps_per_s": sps, "baseline_wall_s": baseline}


def _serve(label="live-serve/lr/d256/r4/batch8", p50=1e-4, p99=2e-4,
           rps=1e3, staleness=3, bound=4, monotone=True, max_v=5,
           baseline=None):
    return {"label": label, "kind": "serve", "p50_s": p50, "p99_s": p99,
            "rps": rps, "max_staleness_steps": staleness,
            "staleness_bound_steps": bound, "versions_monotone": monotone,
            "max_version_served": max_v, "baseline_p50_s": baseline}


def test_gate_clean_rows_pass():
    assert claims.check_bench_live([_conv(), _serve()]) == []
    assert claims.check_bench_live([]) == []


def test_gate_flags_no_convergence():
    bad = claims.check_bench_live([_conv(losses=(10.0, 9.9)), _serve()])
    assert len(bad) == 1 and "no convergence" in bad[0]


def test_gate_flags_staleness_over_bound():
    bad = claims.check_bench_live([_conv(), _serve(staleness=5, bound=4)])
    assert len(bad) == 1 and "exceeded bound" in bad[0]


def test_gate_flags_version_disorder_and_never_published():
    bad = claims.check_bench_live([_conv(), _serve(monotone=False)])
    assert len(bad) == 1 and "backwards" in bad[0]
    bad = claims.check_bench_live([_conv(), _serve(max_v=0)])
    assert len(bad) == 1 and "never served" in bad[0]


def test_gate_flags_broken_pipeline():
    bad = claims.check_bench_live([_conv(), _serve(rps=0.0)])
    assert len(bad) == 1 and "throughput" in bad[0]
    bad = claims.check_bench_live([_conv(), _serve(p50=2e-4, p99=1e-4)])
    assert len(bad) == 1 and "p99 < p50" in bad[0]


def test_gate_flags_regressions_over_tolerance():
    tol = claims.LIVE_REGRESSION_TOL
    ok = [_conv(wall=1.0 * (1 + tol) * 0.99, baseline=1.0),
          _serve(p50=1e-4 * (1 + tol) * 0.99, p99=1.0, baseline=1e-4)]
    assert claims.check_bench_live(ok) == []
    bad = claims.check_bench_live(
        [_conv(wall=1.0 * (1 + tol) * 1.05, baseline=1.0), _serve()])
    assert len(bad) == 1 and "wall time regressed" in bad[0]
    bad = claims.check_bench_live(
        [_conv(), _serve(p50=1e-4 * (1 + tol) * 1.05, p99=1.0,
                         baseline=1e-4)])
    assert len(bad) == 1 and "p50 regressed" in bad[0]
    # cross-host / first-run points carry no baseline and never gate
    assert claims.check_bench_live([_conv(wall=99.0), _serve(p50=9.0,
                                                             p99=9.9)]) == []


def test_gate_rejects_missing_cell_family():
    """Vacuous-green guard: a run measuring only one cell family must
    not validate as green."""
    bad = claims.check_bench_live([_conv()])
    assert len(bad) == 1 and "serve-under-training" in bad[0]
    bad = claims.check_bench_live([_serve()])
    assert len(bad) == 1 and "convergence cells" in bad[0]


# ---------------------------------------------------------------------------
# LiveBenchStore
# ---------------------------------------------------------------------------


def test_live_store_snapshot_deterministic(tmp_path):
    s = LiveBenchStore(tmp_path / "BENCH_live.json",
                       jsonl_path=tmp_path / "runs.jsonl")
    s.record_entry("b/label", {"wall_s": 2.0})
    s.record_entry("a/label", {"wall_s": 1.0}, cached=True)
    s.record_event("live_timing", label="a/label", cell_s=0.1)
    snap = s.snapshot()
    assert list(snap["entries"]) == ["a/label", "b/label"]
    assert "live_timing" not in json.dumps(snap)  # events stay in sidecar
    p = s.write()
    first = p.read_bytes()
    s.write()
    assert p.read_bytes() == first
    assert LiveBenchStore.load(p) == snap


def test_live_store_default_path_is_committed_trajectory():
    assert LiveBenchStore().json_path.name == "BENCH_live.json"


# ---------------------------------------------------------------------------
# Producer end-to-end (micro shapes): trajectory points + reproducibility
# ---------------------------------------------------------------------------


TINY_PROFILES = {
    "ci": dict(d=64, n_batch=32, n_steps=8, merge_every=2, step_size=0.2,
               replicas=(2,), compress=(False, True), serve_replicas=2,
               max_batch=4, n_checkpoints=2),
}


def test_producer_trajectory_and_byte_reproducibility(tmp_path, monkeypatch):
    from benchmarks import bench_live, common

    monkeypatch.setattr(bench_live, "PROFILES", TINY_PROFILES)
    monkeypatch.setattr(common, "RESULTS_DIR", tmp_path / "res")
    out = tmp_path / "BENCH_live.json"

    rows = bench_live.run("ci", out_json=str(out))
    data = json.loads(out.read_text())
    assert len(data["entries"]) == 3   # 1 replica count x 2 compress + serve
    kinds = {e["kind"] for e in data["entries"].values()}
    assert kinds == {"convergence", "serve"}
    for e in data["entries"].values():
        assert {"host", "device_kind", "task", "n_steps"} <= set(e)
        if e["kind"] == "convergence":
            assert len(e["losses"]) == 3          # init + 2 checkpoints
            assert e["losses"][-1] < e["losses"][0]
            assert e["merges"] == 4 and e["steps_per_s"] > 0
        else:
            assert e["p99_s"] >= e["p50_s"] > 0 and e["rps"] > 0
            assert e["max_staleness_steps"] <= e["staleness_bound_steps"]
            assert e["versions_monotone"] is True
            assert e["max_version_served"] >= 1
    # cold run: committed file absent -> no baselines, gate clean
    assert all(r.get("baseline_wall_s") is None
               and r.get("baseline_p50_s") is None for r in rows)
    assert claims.check_bench_live(rows) == []

    first = out.read_bytes()
    rows2 = bench_live.run("ci", out_json=str(out))
    assert out.read_bytes() == first   # warm re-run is byte-identical
    # warm run gates against the (now committed) same-host trajectory
    for r in rows2:
        if r["kind"] == "convergence":
            assert r["baseline_wall_s"] == r["wall_s"]
        else:
            assert r["baseline_p50_s"] == r["p50_s"]
    assert claims.check_bench_live(rows2) == []
