"""nn substrate: chunked attention, mixers, decode==train consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import attention, layers, ssm, xlstm
from repro.kernels.flash_attn.ref import attention_ref


@pytest.mark.parametrize("causal,window", [(True, None), (True, 8),
                                           (False, None)])
def test_chunked_attention_vs_ref(causal, window, rng):
    B, Hq, Hkv, S, hd = 2, 4, 2, 64, 16
    q = jnp.asarray(rng.normal(0, 1, (B, Hq, S, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (B, Hkv, S, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (B, Hkv, S, hd)).astype(np.float32))
    ref = attention_ref(q, jnp.repeat(k, 2, 1), jnp.repeat(v, 2, 1),
                        causal=causal, window=window)
    out = attention.chunked_attention(q, k, v, causal=causal, window=window,
                                      chunk_q=16)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_chunked_attention_windowed_slice_path(rng):
    """sk >> window triggers the static-size dynamic-slice path."""
    B, H, S, hd = 1, 2, 128, 8
    q = jnp.asarray(rng.normal(0, 1, (B, H, S, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (B, H, S, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (B, H, S, hd)).astype(np.float32))
    ref = attention_ref(q, k, v, causal=True, window=16)
    out = attention.chunked_attention(q, k, v, causal=True, window=16,
                                      chunk_q=16)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_decode_attention_matches_full(rng):
    """Decoding the last token over a cache == last row of full attention."""
    B, H, S, hd = 2, 2, 32, 16
    q_all = jnp.asarray(rng.normal(0, 1, (B, H, S, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (B, H, S, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (B, H, S, hd)).astype(np.float32))
    full = attention_ref(q_all, k, v, causal=True)
    dec = attention.decode_attention(q_all[:, :, -1:], k, v, S)
    np.testing.assert_allclose(dec[:, :, 0], full[:, :, -1], rtol=1e-4,
                               atol=1e-4)


def test_rotary_preserves_norm(rng):
    x = jnp.asarray(rng.normal(0, 1, (2, 8, 4, 32)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8)).astype(jnp.int32)
    r = layers.rotary(x, pos)
    np.testing.assert_allclose(jnp.linalg.norm(r, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-4)


def test_rms_norm_unit_scale(rng):
    x = jnp.asarray(rng.normal(0, 5, (4, 64)).astype(np.float32))
    y = layers.rms_norm(x, jnp.ones(64))
    rms = jnp.sqrt(jnp.mean(jnp.square(y), -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_mamba2_chunk_equals_recurrent(rng):
    B, S, d = 2, 32, 16
    p, _, meta = ssm.init_mamba2(jax.random.PRNGKey(0), d, 8, jnp.float32,
                                 head_dim=8)
    x = jnp.asarray(rng.normal(0, 0.5, (B, S, d)).astype(np.float32))
    y_chunk, _ = ssm.mamba2(x, p, meta, chunk=8)
    h, conv = ssm.init_decode_state(B, meta)
    ys = []
    for t in range(S):
        yt, (h, conv) = ssm.mamba2(x[:, t:t + 1], p, meta, state=h,
                                   conv_state=conv)
        ys.append(yt)
    np.testing.assert_allclose(jnp.concatenate(ys, 1), y_chunk, rtol=1e-3,
                               atol=1e-3)


def test_mlstm_chunk_equals_recurrent(rng):
    B, S, d = 2, 32, 16
    p, _, meta = xlstm.init_mlstm(jax.random.PRNGKey(0), d, 2, jnp.float32)
    x = jnp.asarray(rng.normal(0, 0.5, (B, S, d)).astype(np.float32))
    y_chunk, _ = xlstm.mlstm(x, p, meta, chunk=8)
    C = xlstm.init_mlstm_state(B, meta)
    ys = []
    for t in range(S):
        yt, C = xlstm.mlstm(x[:, t:t + 1], p, meta, state=C)
        ys.append(yt)
    np.testing.assert_allclose(jnp.concatenate(ys, 1), y_chunk, rtol=1e-3,
                               atol=1e-3)


def test_slstm_stateful_split(rng):
    B, S, d = 2, 32, 16
    p, _, meta = xlstm.init_slstm(jax.random.PRNGKey(0), d, 2, jnp.float32)
    x = jnp.asarray(rng.normal(0, 0.5, (B, S, d)).astype(np.float32))
    y_full, _ = xlstm.slstm(x, p, meta)
    y_a, st = xlstm.slstm(x[:, :16], p, meta)
    y_b, _ = xlstm.slstm(x[:, 16:], p, meta, state=st)
    np.testing.assert_allclose(jnp.concatenate([y_a, y_b], 1), y_full,
                               rtol=1e-4, atol=1e-4)


def test_moe_routing_mass_conserved(rng):
    from repro.nn import moe
    p, _ = moe.init_moe(jax.random.PRNGKey(0), 16, 32, 8, jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (64, 16)).astype(np.float32))
    out, aux = moe.moe_ffn(x, p, top_k=2, capacity_factor=2.0)
    assert out.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) > 0
    # generous capacity => no drops => output differs from zero for all tokens
    assert float(jnp.min(jnp.sum(jnp.abs(out), axis=-1))) > 0


def test_moe_capacity_drops_tokens(rng):
    from repro.nn import moe
    p, _ = moe.init_moe(jax.random.PRNGKey(0), 16, 32, 8, jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (64, 16)).astype(np.float32))
    out_full, _ = moe.moe_ffn(x, p, top_k=2, capacity_factor=8.0)
    out_tight, _ = moe.moe_ffn(x, p, top_k=2, capacity_factor=0.25)
    # tight capacity changes (drops) some token outputs
    assert float(jnp.max(jnp.abs(out_full - out_tight))) > 1e-6
