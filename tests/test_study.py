"""Study subsystem: specs, trial cache, stacking, store, tuner, advisor.

The acceptance contract of the subsystem (ISSUE 2):
* ``advisor.recommend`` ranks configurations for every Table-3 synthetic
  dataset, deterministically under a fixed seed;
* the ranking has the paper's qualitative Table-6 structure — sync
  preferred where async replication hurts statistical efficiency, and
  vice versa;
* a sweep re-run hits the trial cache and reproduces the structured
  results byte-for-byte.
"""
import json
import math

import numpy as np
import pytest

from repro.core import sgd
from repro.data import synthetic
from repro.study import advisor, claims, spec, store, tuner
from repro.study.runner import Runner, TrialResult

CAPS = advisor.HostCaps(parallel_width=8, max_replicas=64, backends={})


def _trial(name="covtype", task="lr", strategy=None, step=1e-2, epochs=3,
           max_n=128):
    return spec.TrialSpec(
        dataset=spec.DatasetSpec(name, max_n=max_n), task=task,
        strategy=strategy or sgd.SyncSGD(), step=step, epochs=epochs)


# ---------------------------------------------------------------------------
# spec: keys, round-trips, grids
# ---------------------------------------------------------------------------


def test_trial_key_content_hash_is_stable_and_step_sensitive():
    a, b = _trial(step=1e-2), _trial(step=1e-2)
    assert a.key == b.key                       # same content, same key
    assert a.key != _trial(step=1e-1).key       # step is part of the key
    assert a.stack_key == _trial(step=1e-1).stack_key  # ... but not the stack
    assert a.stack_key != _trial(task="svm", step=1e-1).stack_key


def test_strategy_round_trip_through_dict():
    for s in (sgd.SyncSGD(), sgd.SyncSGD(batch=16, kernel_backend="reference"),
              sgd.AsyncLocalSGD(replicas=16, local_batch=4, rep_k=2,
                                access="round_robin",
                                kernel_backend="reference")):
        assert spec.strategy_from_dict(spec.strategy_to_dict(s)) == s


def test_trial_spec_round_trip():
    t = _trial(strategy=sgd.AsyncLocalSGD(replicas=4), epochs=7)
    assert spec.TrialSpec.from_dict(t.to_dict()) == t
    assert spec.TrialSpec.from_dict(json.loads(json.dumps(t.to_dict()))) == t


def test_dataset_spec_rejects_unknown_and_half_shapes():
    with pytest.raises(ValueError, match="unknown dataset"):
        spec.DatasetSpec("imagenet")
    with pytest.raises(ValueError, match="both n and d"):
        spec.DatasetSpec("custom", n=64)


def test_dataset_profile_matches_loaded_data():
    for ds in (spec.DatasetSpec("covtype", max_n=128),
               spec.DatasetSpec("w8a", max_n=128),
               spec.DatasetSpec("toy", n=96, d=8)):
        prof, data = ds.profile(), ds.load()
        assert (prof.n, prof.d, prof.dense) == (data.n, data.d, data.dense)


def test_grid_filters_oversized_replica_counts():
    trials = spec.grid(
        [spec.DatasetSpec("covtype", max_n=128)], ("lr",),
        [sgd.SyncSGD(), sgd.AsyncLocalSGD(replicas=64),
         sgd.AsyncLocalSGD(replicas=128)],
        steps=(1e-2, 1e-1), epochs=3)
    names = {t.strategy.name for t in trials}
    assert len(trials) == 4  # (sync + r64) x 2 steps; r128 needs n >= 256
    assert not any("r128" in n for n in names)


# ---------------------------------------------------------------------------
# runner: cache, stacking
# ---------------------------------------------------------------------------


def test_trial_cache_roundtrip_and_hit(tmp_path):
    r = Runner(cache_dir=tmp_path / "cache")
    t = _trial(epochs=3)
    first = r.run_trial(t)
    assert not first.cached
    second = r.run_trial(t)
    assert second.cached
    np.testing.assert_array_equal(first.losses, second.losses)
    np.testing.assert_array_equal(first.epoch_times, second.epoch_times)
    # a different spec is a miss
    assert not r.run_trial(_trial(epochs=4)).cached


def test_interrupted_sweep_resumes_from_cache(tmp_path):
    """Only the missing trials of a partially-cached sweep are executed."""
    trials = [_trial(step=s, epochs=3) for s in (1e-3, 1e-2, 1e-1)]
    r1 = Runner(cache_dir=tmp_path / "cache")
    r1.run(trials[:2])
    r2 = Runner(cache_dir=tmp_path / "cache")
    out = r2.run(trials)
    assert [t.cached for t in out] == [True, True, False]


def test_stacked_step_grid_matches_single_runs():
    """vmap-stacked step grids reproduce per-trial runs (same program up
    to vmap) for sync and async strategies."""
    for strategy in (sgd.SyncSGD(),
                     sgd.AsyncLocalSGD(replicas=4, local_batch=2)):
        trials = [_trial(strategy=strategy, step=s, epochs=3)
                  for s in (1e-3, 1e-2, 1e-1)]
        stacked = Runner(stack=True).run(trials)
        singles = Runner(stack=False).run(trials)
        assert [t.stacked for t in stacked] == [True] * 3
        assert [t.stacked for t in singles] == [False] * 3
        for a, b in zip(stacked, singles):
            np.testing.assert_allclose(a.losses, b.losses,
                                       rtol=1e-4, atol=1e-4)


def test_kernel_backend_trials_do_not_stack():
    strat = sgd.SyncSGD(kernel_backend="reference")
    trials = [_trial(strategy=strat, step=s, epochs=2) for s in (1e-3, 1e-2)]
    out = Runner(stack=True).run(trials)
    assert [t.stacked for t in out] == [False, False]


def test_runner_records_into_store(tmp_path):
    st = store.StudyStore(tmp_path / "out.json")
    r = Runner(cache_dir=tmp_path / "cache", store=st)
    t = _trial(epochs=2)
    r.run_trial(t)
    assert t.key in st.trials
    assert st.trials[t.key]["spec"] == t.to_dict()


# ---------------------------------------------------------------------------
# store: deterministic snapshots
# ---------------------------------------------------------------------------


def test_store_snapshot_identical_across_cached_reruns(tmp_path):
    """The acceptance property behind CI's study-smoke job, in miniature:
    the same sweep run twice (second time from cache) writes
    byte-identical BENCH_study.json."""
    trials = [_trial(step=s, epochs=3) for s in (1e-2, 1e-1)]

    def sweep(path):
        st = store.StudyStore(path, jsonl_path=tmp_path / "runs.jsonl")
        Runner(cache_dir=tmp_path / "cache", store=st).run(trials)
        st.record_claims([], checked_modules=["mini"])
        return st.write().read_text()

    first = sweep(tmp_path / "a.json")
    second = sweep(tmp_path / "b.json")
    assert first == second
    # and the JSONL sidecar logged one line per sweep
    lines = (tmp_path / "runs.jsonl").read_text().splitlines()
    assert len(lines) == 2
    assert json.loads(lines[1])["n_cached"] == 2


def test_store_snapshot_round_trips_trial_results(tmp_path):
    st = store.StudyStore(tmp_path / "out.json")
    r = Runner(cache_dir=tmp_path / "cache", store=st)
    t = _trial(epochs=2)
    res = r.run_trial(t)
    st.write()
    loaded = store.StudyStore.load(tmp_path / "out.json")
    rec = loaded["trials"][t.key]
    assert spec.TrialSpec.from_dict(rec["spec"]) == t
    restored = TrialResult.from_dict(rec)
    np.testing.assert_array_equal(restored.losses, res.losses)


# ---------------------------------------------------------------------------
# tuner
# ---------------------------------------------------------------------------


def test_tune_step_selects_converging_step():
    t = tuner.tune_step(Runner(), _trial(epochs=6),
                        steps=(1e-6, 1e-2, 1e-1))
    assert t.best_step in (1e-2, 1e-1)
    assert set(t.results) == {1e-6, 1e-2, 1e-1}
    # the winner reaches the derived target; the tiny step does not
    assert t.best_result.epochs_to(t.target) is not None
    assert t.results[1e-6].epochs_to(t.target) is None


def test_tune_step_epochs_mode_is_wall_clock_free():
    a = tuner.tune_step(Runner(), _trial(epochs=4), steps=(1e-2, 1e-1),
                        by="epochs")
    b = tuner.tune_step(Runner(), _trial(epochs=4), steps=(1e-2, 1e-1),
                        by="epochs")
    assert a.best_step == b.best_step


class _TiedRunner:
    """Stub runner: every step yields the identical loss curve (full tie)."""

    def run(self, trials):
        return [TrialResult(losses=np.array([1.0, 0.5, 0.1]),
                            epoch_times=np.array([0.1, 0.1]),
                            strategy=t.strategy.name, task=t.task)
                for t in trials]


def test_tune_step_tie_breaks_on_canonical_step_order():
    """Rank ties resolve to the smallest step, independent of the order
    the grid arrives in — multi-worker and single-host sweeps must pick
    identical steps from identical results."""
    base = _trial(epochs=2)
    for steps in [(1e-2, 1e-1, 1e-3), (1e-1, 1e-3, 1e-2), (1e-3, 1e-2, 1e-1)]:
        t = tuner.tune_step(_TiedRunner(), base, steps=steps, by="epochs")
        assert t.best_step == 1e-3


def test_tune_many_matches_per_base_tune_step():
    """One batched dispatch, same answers: tune_many is tune_step mapped
    over bases (incl. per-base target derivation)."""
    bases = [_trial(strategy=sgd.SyncSGD(), epochs=4),
             _trial(strategy=sgd.AsyncLocalSGD(replicas=4), epochs=4)]
    runner = Runner()
    many = tuner.tune_many(runner, bases, steps=(1e-6, 1e-2, 1e-1),
                           by="epochs")
    singles = [tuner.tune_step(runner, b, steps=(1e-6, 1e-2, 1e-1),
                               by="epochs") for b in bases]
    assert len(many) == 2
    for m, s in zip(many, singles):
        assert m.best_step == s.best_step
        assert m.target == s.target
        assert set(m.results) == set(s.results)


# ---------------------------------------------------------------------------
# advisor: Table 6
# ---------------------------------------------------------------------------


def test_modeled_epoch_cost_reproduces_hardware_trades():
    prof = spec.DatasetSpec("covtype", max_n=1024).profile()
    cost = lambda s: advisor.modeled_epoch_cost(prof, s, CAPS)
    # more replicas => cheaper epochs (paper Fig. 12)
    assert (cost(sgd.AsyncLocalSGD(replicas=16))
            < cost(sgd.AsyncLocalSGD(replicas=4)))
    # rep-k halos cost hardware efficiency (Fig. 15)
    assert (cost(sgd.AsyncLocalSGD(replicas=8, rep_k=10))
            > cost(sgd.AsyncLocalSGD(replicas=8)))
    # full-batch sync is the cheapest pass on a wide host (Fig. 22)
    assert cost(sgd.SyncSGD()) < cost(sgd.AsyncLocalSGD(replicas=8))
    # more frequent merges cost more
    assert (cost(sgd.AsyncLocalSGD(replicas=8, merge_every=0.25))
            > cost(sgd.AsyncLocalSGD(replicas=8, merge_every=1.0)))


@pytest.mark.parametrize("name", list(synthetic.PAPER_DATASETS))
def test_recommend_every_table3_dataset_deterministically(name):
    """recommend() returns a full ranked table for each Table-3 dataset
    and is bit-deterministic under a fixed seed (rank="modeled": no wall
    clock in the decision)."""
    max_n = 64 if name == "news" else 128
    dspec = spec.DatasetSpec(name, max_n=max_n)
    space = [sgd.SyncSGD(), sgd.AsyncLocalSGD(replicas=4, local_batch=1)]
    runner = Runner()  # shared dataset memo; no cache — both calls recompute
    recs = [advisor.recommend(dspec.profile(), CAPS, runner=runner,
                              epochs=4, steps=(1e-2, 1e-1), space=space,
                              seed=0)
            for _ in range(2)]
    for rec in recs:
        assert rec.dataset == name
        assert len(rec.ranked) == len(space)
        assert [r.score for r in rec.ranked] == sorted(
            r.score for r in rec.ranked)
        for row in rec.ranked:
            assert row.epoch_cost > 0
            assert 0 < row.hw_advantage <= 1.0
            assert np.isfinite(row.final_loss)
    assert [r.name for r in recs[0].ranked] == [r.name for r in recs[1].ranked]
    assert [r.score for r in recs[0].ranked] == [r.score for r in recs[1].ranked]
    assert recs[0].target == recs[1].target


def test_recommend_qualitative_table6_structure():
    """The paper's Table-6 finding, reproduced: on covtype async
    replication hurts statistical efficiency outright (no async config
    reaches 1% of the optimum) => sync preferred; on a larger w8a slice
    the tuned async configuration reaches the better optimum that the
    batch path cannot => async preferred.  The winner is always the
    config whose statistical-efficiency penalty is outweighed by its
    hardware advantage."""
    space = [sgd.SyncSGD(), sgd.AsyncLocalSGD(replicas=4, local_batch=1)]
    runner = Runner()

    sync_rec = advisor.recommend(
        spec.DatasetSpec("covtype", max_n=192).profile(), CAPS,
        runner=runner, epochs=8, steps=(1e-2, 1e-1), space=space)
    assert isinstance(sync_rec.best.strategy, sgd.SyncSGD)
    async_row = next(r for r in sync_rec.ranked
                     if isinstance(r.strategy, sgd.AsyncLocalSGD))
    assert async_row.epochs_to_target is None      # replication hurt: no hit
    assert math.isinf(async_row.stat_penalty)

    async_rec = advisor.recommend(
        spec.DatasetSpec("w8a", max_n=512).profile(), CAPS,
        runner=runner, epochs=10, steps=(1e-3, 1e-2, 1e-1), space=space)
    assert isinstance(async_rec.best.strategy, sgd.AsyncLocalSGD)
    sync_row = next(r for r in async_rec.ranked
                    if isinstance(r.strategy, sgd.SyncSGD))
    assert async_rec.best.epochs_to_target is not None
    assert sync_row.epochs_to_target is None       # batch path missed target

    # consistency of the trade on both: the winner minimizes
    # epochs_to x epoch_cost among candidates, i.e. wins exactly when its
    # statistical penalty is covered by its hardware advantage
    for rec in (sync_rec, async_rec):
        finite = [r for r in rec.ranked if math.isfinite(r.score)]
        assert finite and rec.best is finite[0]
        for row in finite:
            assert row.score == pytest.approx(
                row.epochs_to_target * row.epoch_cost)


def test_recommend_rank_measured_uses_wall_time():
    rec = advisor.recommend(
        spec.DatasetSpec("covtype", max_n=128).profile(), CAPS,
        runner=Runner(), epochs=4, steps=(1e-2, 1e-1),
        space=[sgd.SyncSGD()], rank="measured")
    assert rec.rank_by == "measured"
    row = rec.best
    assert row.epoch_cost == pytest.approx(row.measured_time_per_epoch_s)


def test_recommend_to_dict_serializes():
    rec = advisor.recommend(
        spec.DatasetSpec("covtype", max_n=128).profile(), CAPS,
        runner=Runner(), epochs=3, steps=(1e-2,),
        space=[sgd.SyncSGD(), sgd.AsyncLocalSGD(replicas=4)])
    dct = json.loads(json.dumps(rec.to_dict()))
    assert dct["dataset"] == "covtype"
    assert len(dct["ranked"]) == 2
    assert spec.strategy_from_dict(dct["ranked"][0]["strategy"]) == \
        rec.best.strategy


def _calibration_store(k=2e-6, U=24.0, M=3.0, caps=CAPS):
    """A synthetic BENCH_study-shaped snapshot whose measured wall times
    follow the cost model exactly, with known constants."""
    strats = [sgd.SyncSGD(), sgd.SyncSGD(batch=8), sgd.SyncSGD(batch=32),
              sgd.AsyncLocalSGD(replicas=4), sgd.AsyncLocalSGD(replicas=16),
              sgd.AsyncLocalSGD(replicas=8, rep_k=4),
              sgd.AsyncLocalSGD(replicas=8, merge_every=0.25),
              sgd.AsyncLocalSGD(replicas=4, local_batch=4)]
    trials = {}
    for name, max_n in (("covtype", 128), ("w8a", 256)):
        ds = spec.DatasetSpec(name, max_n=max_n)
        prof = ds.profile()
        for s in strats:
            t = spec.TrialSpec(ds, "lr", s, 1e-2, 4)
            base, u, m = advisor.cost_features(prof, s, caps)
            trials[t.key] = {
                "spec": t.to_dict(),
                "derived": {"time_per_epoch_s": k * (base + U * u + M * m)},
            }
    return {"trials": trials}


def test_cost_features_decomposition_matches_modeled_cost():
    prof = spec.DatasetSpec("covtype", max_n=1024).profile()
    for s in (sgd.SyncSGD(), sgd.SyncSGD(batch=16),
              sgd.AsyncLocalSGD(replicas=8, rep_k=10, merge_every=0.5)):
        base, u, m = advisor.cost_features(prof, s, CAPS)
        assert advisor.modeled_epoch_cost(prof, s, CAPS) == pytest.approx(
            base + advisor.UPDATE_OVERHEAD * u + advisor.MERGE_UNIT * m)


def test_calibrate_recovers_planted_constants_and_is_deterministic():
    snap = _calibration_store(k=2e-6, U=24.0, M=3.0)
    model = advisor.calibrate(snap, CAPS)
    assert model.source == "calibrated"
    assert model.n_trials == len(snap["trials"])
    assert model.scale == pytest.approx(2e-6)
    assert model.update_overhead == pytest.approx(24.0)
    assert model.merge_unit == pytest.approx(3.0)
    assert advisor.calibrate(snap, CAPS) == model


def test_calibrate_falls_back_below_min_trials_and_on_degenerate_fits():
    assert advisor.calibrate({"trials": {}}, CAPS) == \
        advisor.DEFAULT_COST_MODEL
    # below the floor even with valid rows
    snap = _calibration_store()
    few = {"trials": dict(list(snap["trials"].items())[2:5])}  # sync + async
    assert advisor.calibrate(few, CAPS) == advisor.DEFAULT_COST_MODEL
    assert advisor.calibrate(few, CAPS, min_trials=3).source == "calibrated"
    # sync-only stores can't identify the merge constant: rank-deficient
    sync_only = {"trials": {
        key: rec for key, rec in snap["trials"].items()
        if rec["spec"]["strategy"]["kind"] == "sync"}}
    assert advisor.calibrate(sync_only, CAPS, min_trials=3) == \
        advisor.DEFAULT_COST_MODEL
    # junk records are skipped, not fatal
    junk = {"trials": {"x": {"spec": {}},
                       "y": {"derived": {"time_per_epoch_s": -1.0}}}}
    assert advisor.calibrate(junk, CAPS) == advisor.DEFAULT_COST_MODEL


def test_calibrate_skips_records_whose_key_this_host_cannot_reproduce():
    """Wall-times measured against data this host doesn't have (stored
    key != locally recomputed key, e.g. a full-download store calibrated
    on a fixtures-only host) must not contribute features to the fit."""
    snap = _calibration_store()
    # remap every record under a foreign key: nothing is fittable
    foreign = {"trials": {f"deadbeef{i:08x}": rec for i, rec in
                          enumerate(snap["trials"].values())}}
    assert advisor.calibrate(foreign, CAPS) == advisor.DEFAULT_COST_MODEL
    # a real-dataset record this host cannot resolve at all (no download,
    # no bundled fixture) is skipped, not a crash
    mixed = dict(snap["trials"])
    mixed["feedfacefeedface"] = {
        "spec": {"dataset": {"name": "rcv1", "source": "real"},
                 "task": "lr", "strategy": {"kind": "sync"},
                 "step": 1e-2, "epochs": 4, "seed": 0},
        "derived": {"time_per_epoch_s": 1.0},
    }
    model = advisor.calibrate({"trials": mixed}, CAPS)
    assert model.source == "calibrated"
    assert model.n_trials == len(snap["trials"])    # rcv1 contributed nothing
    # a store whose keys check out still fits
    assert advisor.calibrate(snap, CAPS).source == "calibrated"


def test_calibrate_reads_a_written_store(tmp_path):
    st = store.StudyStore(tmp_path / "out.json")
    r = Runner(cache_dir=tmp_path / "cache", store=st)
    for s in (1e-3, 1e-2, 1e-1):
        r.run_trial(_trial(step=s, epochs=2))
    st.write()
    # 3 trials < floor -> defaults, via path, snapshot dict, and StudyStore
    for src in (tmp_path / "out.json", str(tmp_path / "out.json"),
                store.StudyStore.load(tmp_path / "out.json"), st):
        assert advisor.calibrate(src, CAPS) == advisor.DEFAULT_COST_MODEL


def test_recommend_rank_calibrated_uses_fitted_model():
    model = advisor.calibrate(_calibration_store(), CAPS)
    space = [sgd.SyncSGD(), sgd.AsyncLocalSGD(replicas=4, local_batch=1)]
    prof = spec.DatasetSpec("covtype", max_n=128).profile()
    rec = advisor.recommend(prof, CAPS, runner=Runner(), epochs=3,
                            steps=(1e-2,), space=space,
                            rank="calibrated", cost_model=model)
    assert rec.rank_by == "calibrated"
    for row in rec.ranked:
        assert row.epoch_cost == pytest.approx(advisor.modeled_epoch_cost(
            prof, row.strategy, CAPS, model=model))
    # no model supplied -> fixed defaults (same numbers as rank="modeled")
    rec_default = advisor.recommend(prof, CAPS, runner=Runner(), epochs=3,
                                    steps=(1e-2,), space=space,
                                    rank="calibrated")
    rec_modeled = advisor.recommend(prof, CAPS, runner=Runner(), epochs=3,
                                    steps=(1e-2,), space=space)
    assert [r.epoch_cost for r in rec_default.ranked] == \
        [r.epoch_cost for r in rec_modeled.ranked]
    with pytest.raises(ValueError, match="rank"):
        advisor.recommend(prof, CAPS, runner=Runner(), epochs=2,
                          steps=(1e-2,), space=space, rank="bogus")
    # a supplied model is never silently ignored: wrong rank is an error
    with pytest.raises(ValueError, match="cost_model"):
        advisor.recommend(prof, CAPS, runner=Runner(), epochs=2,
                          steps=(1e-2,), space=space, cost_model=model)


def test_hostcaps_detect_reads_jax_devices_and_registry():
    import jax

    caps = advisor.HostCaps.detect()
    devices = jax.devices()
    assert caps.device_count == len(devices)
    assert caps.platform == devices[0].platform
    per_device = caps.parallel_width // caps.device_count
    assert per_device >= 8      # at least the CPU lane floor
    for fam in ("glm_grad", "glm_sgd", "glm_sparse"):
        assert "reference" in caps.backends[fam]
    dct = caps.to_dict()
    assert dct["platform"] == caps.platform
    assert isinstance(dct["backends"]["glm_grad"], list)


def test_candidate_space_respects_host_and_dataset():
    prof = spec.DatasetSpec("covtype", max_n=128).profile()  # n=128
    small_caps = advisor.HostCaps(parallel_width=8, max_replicas=16,
                                  backends={"glm_grad": ("reference",)})
    space = advisor.candidate_space(prof, small_caps,
                                    kernel_backends=(None, "reference",
                                                     "pallas-tpu"))
    names = [getattr(s, "name") for s in space]
    assert "sync" in names and "sync[reference]" in names
    assert not any("pallas-tpu" in n for n in names)   # host can't run it
    assert not any(getattr(s, "replicas", 0) > 16 for s in space)
    # rep-k never exceeds the partition size
    assert all(s.rep_k < prof.n // s.replicas for s in space
               if isinstance(s, sgd.AsyncLocalSGD))


# ---------------------------------------------------------------------------
# claims predicates (moved out of benchmarks/run.py)
# ---------------------------------------------------------------------------


def test_claims_table4_flags_broken_identity_and_slowdown():
    rows = [dict(dataset="covtype", task="lr",
                 paths_statistically_identical=True, speedup_sync_vs_seq=9.0)]
    assert claims.check_table4(rows) == []
    rows[0]["paths_statistically_identical"] = False
    rows[0]["speedup_sync_vs_seq"] = 0.5
    bad = claims.check_table4(rows)
    assert len(bad) == 2
    assert any("identity" in b for b in bad)


def test_claims_fig11_flags_replication_improving_statistics():
    rows = [dict(dataset="d", task="lr", replicas=1, final_loss=100.0),
            dict(dataset="d", task="lr", replicas=64, final_loss=101.0)]
    assert claims.check_fig11(rows) == []
    rows[1]["final_loss"] = 50.0   # thread beating kernel outright
    assert len(claims.check_fig11(rows)) == 1


def test_claims_fig14_flags_rep_k_hardware_inversion():
    rows = [dict(dataset="d", task="lr", rep_k=0, t_epoch_ms=1.0),
            dict(dataset="d", task="lr", rep_k=10, t_epoch_ms=1.2)]
    assert claims.check_fig14(rows) == []
    rows[1]["t_epoch_ms"] = 0.5
    assert len(claims.check_fig14(rows)) == 1


def test_claims_validate_dispatches_known_modules():
    results = {
        "table4_sync": [dict(dataset="d", task="lr",
                             paths_statistically_identical=False,
                             speedup_sync_vs_seq=2.0)],
        "unknown_module": [dict(x=1)],
    }
    bad = claims.validate(results)
    assert len(bad) == 1 and bad[0].startswith("table4")
