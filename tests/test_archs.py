"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; prefill->decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.nn import decode, transformer


def make_inputs(cfg, b, s, rng, with_labels=True):
    ins = {}
    if cfg.emb_in():
        ins["embeddings"] = jnp.asarray(
            rng.normal(0, 1, (b, s, cfg.d_model)).astype(np.float32))
    else:
        ins["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                    dtype=jnp.int32)
    if cfg.family == "vlm":
        ins["memory"] = jnp.asarray(
            rng.normal(0, 1, (b, cfg.n_memory, cfg.d_model)).astype(np.float32))
    if with_labels:
        ins["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                    dtype=jnp.int32)
    return ins


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_arch_smoke_train_step(arch, rng):
    cfg = configs.reduced(configs.get(arch))
    params, specs = transformer.init_params(cfg, jax.random.PRNGKey(0))
    # spec tree mirrors param tree
    assert (jax.tree.structure(params) ==
            jax.tree.structure(specs, is_leaf=lambda x: not isinstance(x, dict)))
    B, S = 2, 16
    ins = make_inputs(cfg, B, S, rng)
    h = transformer.forward(params, cfg, ins)
    assert h.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h)))
    loss, grads = jax.value_and_grad(
        lambda p: transformer.loss_fn(p, cfg, ins))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    # one SGD step decreases loss on the same batch (sanity; lr scaled by
    # the gradient norm so stiff architectures like xLSTM don't overshoot)
    lr = 0.05 / max(1.0, np.sqrt(gnorm) / 50.0)
    p2 = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    loss2 = transformer.loss_fn(p2, cfg, ins)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_arch_decode_step(arch, rng):
    cfg = configs.reduced(configs.get(arch))
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    cache, _ = decode.init_cache(cfg, B, S)
    ins = make_inputs(cfg, B, 1, rng, with_labels=False)
    logits, cache2 = decode.decode_step(params, cfg, cache, ins, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["minitron-4b", "h2o-danube-1.8b",
                                  "zamba2-1.2b", "xlstm-1.3b"])
def test_prefill_then_decode_matches_full_forward(arch, rng):
    """logits(prefill S tokens, then decode token S) == logits from a full
    forward over S+1 tokens — the serving path is consistent with training."""
    # danube: window must cover the full test context for ref equivalence
    over = {"window": 64} if configs.get(arch).window else {}
    cfg = configs.reduced(configs.get(arch), **over)
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    full_ins = make_inputs(cfg, B, S + 1, rng, with_labels=False)
    toks = full_ins["tokens"]

    # reference: full forward over S+1, take logits at the last position
    h = transformer.forward(params, cfg, {"tokens": toks})
    ref_logits = (h[:, -1] @ params["embed"].T).astype(jnp.float32)

    # prefill S tokens, then decode token S
    _, cache = transformer.forward(params, cfg, {"tokens": toks[:, :S]},
                                   mode="prefill")
    # pad kv caches by 8 slots so decode at idx=S does not wrap
    def pad_kv(c):
        out = dict(c)
        for k in ("k", "v", "attn_k", "attn_v"):
            if k in out:
                x = out[k]
                out[k] = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, 8), (0, 0)])
        return out

    logits, _ = decode.decode_step(params, cfg, pad_kv(cache),
                                   {"tokens": toks[:, S:S + 1]},
                                   jnp.int32(S))
    np.testing.assert_allclose(logits, ref_logits, rtol=2e-2, atol=2e-2)


def test_swa_ring_cache_decode(rng):
    """Danube's ring cache: decoding past the window stays finite and only
    attends to the last `window` tokens."""
    cfg = configs.reduced(configs.get("h2o-danube-1.8b"), window=8)
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B = 1
    cache, _ = decode.init_cache(cfg, B, 8)   # ring of 8 slots
    assert cache["k"].shape[3] == 8
    for t in range(20):                        # decode well past the window
        ins = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)),
                                     dtype=jnp.int32)}
        logits, cache = decode.decode_step(params, cfg, cache, ins,
                                           jnp.int32(t))
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch,expected_cells", [
    ("minitron-4b", 3), ("h2o-danube-1.8b", 4), ("zamba2-1.2b", 4),
    ("xlstm-1.3b", 4), ("kimi-k2-1t-a32b", 3)])
def test_cell_assignment(arch, expected_cells):
    cells = [c for c in configs.cells() if c[0] == arch]
    assert len(cells) == expected_cells


def test_total_cells():
    # 10 archs x 4 shapes - 7 long_500k skips = 33 runnable cells
    assert len(configs.cells()) == 33
    assert len(configs.cells(include_skipped=True)) == 40
