"""Parallel-SGD engine: partitioning, replication, and the paper's
qualitative claims as executable assertions."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import glm, sgd
from repro.data import synthetic


def test_partition_chunk_covers_exactly():
    parts = sgd.partition_indices(64, 4, "chunk", rep_k=0)
    assert parts.shape == (4, 16)
    assert sorted(parts.reshape(-1).tolist()) == list(range(64))
    # chunk = contiguous ranges
    assert (np.diff(parts, axis=1) == 1).all()


def test_partition_round_robin_strides():
    parts = sgd.partition_indices(64, 4, "round_robin")
    assert (np.diff(parts, axis=1) == 4).all()
    assert sorted(parts.reshape(-1).tolist()) == list(range(64))


def test_partition_rep_k_halo():
    parts = sgd.partition_indices(64, 4, "chunk", rep_k=3)
    assert parts.shape == (4, 19)
    # halo of replica r = first 3 examples of replica (r+1) % 4
    for r in range(4):
        np.testing.assert_array_equal(
            parts[r, -3:], parts[(r + 1) % 4, :3])


def test_partition_rep_k_exceeds_per_wraps_across_partitions():
    """rep_k > per: the halo wraps past the next partition (cyclic stream)."""
    parts = sgd.partition_indices(16, 4, "chunk", rep_k=6)  # per = 4
    assert parts.shape == (4, 10)
    for r in range(4):
        stream = np.concatenate([parts[(r + 1) % 4, :4], parts[(r + 2) % 4, :4]])
        np.testing.assert_array_equal(parts[r, 4:], stream[:6])
    # indices stay in range even when the halo wraps all the way around
    full = sgd.partition_indices(16, 4, "chunk", rep_k=16)
    assert full.min() >= 0 and full.max() < 16


@pytest.mark.parametrize("n", [64, 66])  # 66: n % replicas != 0 (tail dropped)
def test_round_robin_and_chunk_cover_the_same_examples(n):
    """Access path changes the assignment, never the covered example set."""
    ch = sgd.partition_indices(n, 4, "chunk")
    rr = sgd.partition_indices(n, 4, "round_robin")
    assert ch.shape == rr.shape == (4, n // 4)
    assert sorted(ch.reshape(-1).tolist()) == sorted(rr.reshape(-1).tolist())
    assert sorted(ch.reshape(-1).tolist()) == list(range(4 * (n // 4)))


def test_run_result_never_converging():
    """epochs_to/time_to return None when the target is never reached."""
    res = sgd.RunResult(
        losses=np.asarray([1.0, 0.9, 0.85]),
        epoch_times=np.asarray([0.1, 0.2]),
        strategy="sync", task="lr",
    )
    assert res.epochs_to(0.5) is None
    assert res.time_to(0.5) is None
    # converging at init: zero epochs, zero time
    assert res.epochs_to(1.0) == 0
    assert res.time_to(1.0) == 0.0
    # converging mid-run sums only the epochs actually spent
    assert res.epochs_to(0.9) == 1
    assert res.time_to(0.9) == pytest.approx(0.1)


def test_merge_replicas_mean():
    W = jnp.asarray(np.arange(12, dtype=np.float32).reshape(4, 3))
    M = sgd.merge_replicas(W)
    assert M.shape == W.shape
    np.testing.assert_allclose(M[0], W.mean(0))
    np.testing.assert_allclose(M, jnp.broadcast_to(W.mean(0), W.shape))


@pytest.fixture(scope="module")
def ds():
    return synthetic.make_dense("toy", 512, 16, seed=2)


def test_paper_claim_more_replicas_worse_statistical_efficiency(ds):
    """Paper §5.2.2: 'the more replicas, the lower the statistical
    efficiency' — fewer merges of more-diverged models learn less per epoch."""
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    prob = glm.GLMProblem("lr", X, y, 5e-3)
    losses = {}
    for r in (2, 16):
        res = sgd.run(prob, sgd.AsyncLocalSGD(replicas=r, local_batch=8), 6)
        losses[r] = res.losses[-1]
    assert losses[16] >= losses[2] * 0.999, losses


def test_paper_claim_rep_k_improves_statistical_efficiency(ds):
    """Paper §5.2.3: k-wise replication extracts more information per pass."""
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    prob = glm.GLMProblem("lr", X, y, 5e-3)
    res0 = sgd.run(prob, sgd.AsyncLocalSGD(replicas=8, local_batch=4,
                                           rep_k=0), 6)
    resk = sgd.run(prob, sgd.AsyncLocalSGD(replicas=8, local_batch=4,
                                           rep_k=16), 6)
    assert resk.losses[-1] <= res0.losses[-1] * 1.001


def test_sync_engine_kernel_backend_matches_xla_path(ds):
    """SyncSGD routed through the kernel dispatch registry reproduces the
    inline-XLA epoch (full-batch via glm_grad, mini-batch via glm_sgd)."""
    from repro.kernels import common as kcommon

    X, y = jnp.asarray(ds.X[:64]), jnp.asarray(ds.y[:64])
    prob = glm.GLMProblem("lr", X, y, 5e-3)
    for batch in (None, 16):
        base = sgd.run(prob, sgd.SyncSGD(batch=batch), 3, record_time=False)
        for backend in kcommon.available_backends("glm_grad"):
            res = sgd.run(
                prob, sgd.SyncSGD(batch=batch, kernel_backend=backend), 3,
                record_time=False)
            np.testing.assert_allclose(res.losses, base.losses,
                                       rtol=1e-4, atol=1e-4)


def test_sync_engine_kernel_backend_sparse(ds):
    """Sparse SyncSGD routes through the registry: full-batch via the
    glm_sparse sum gradient, mini-batch via the fused glm_sgd_sparse
    epoch — both reproduce the inline-XLA path."""
    from repro.kernels import common as kcommon

    sp = synthetic.make_sparse("sp-engine", 64, 128, 5.0, 8, seed=4)
    prob = ("lr", sp.ell, jnp.asarray(sp.y), 0.05)
    for batch in (None, 16):
        base = sgd.run(prob, sgd.SyncSGD(batch=batch), 3, sparse_data=True,
                       record_time=False)
        for backend in kcommon.available_backends(
                "glm_sparse", info={"sparse": True, "n": 64, "d": 128}):
            res = sgd.run(prob, sgd.SyncSGD(batch=batch,
                                            kernel_backend=backend), 3,
                          sparse_data=True, record_time=False)
            np.testing.assert_allclose(res.losses, base.losses,
                                       rtol=1e-4, atol=1e-4)


def test_access_path_changes_assignment_not_semantics(ds):
    """row-rr vs row-ch assign different examples but both converge."""
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    prob = glm.GLMProblem("lr", X, y, 5e-3)
    for access in ("chunk", "round_robin"):
        res = sgd.run(prob, sgd.AsyncLocalSGD(replicas=4, local_batch=8,
                                              access=access), 6)
        assert res.losses[-1] < res.losses[0]
