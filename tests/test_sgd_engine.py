"""Parallel-SGD engine: partitioning, replication, and the paper's
qualitative claims as executable assertions."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import glm, sgd
from repro.data import synthetic


def test_partition_chunk_covers_exactly():
    parts = sgd.partition_indices(64, 4, "chunk", rep_k=0)
    assert parts.shape == (4, 16)
    assert sorted(parts.reshape(-1).tolist()) == list(range(64))
    # chunk = contiguous ranges
    assert (np.diff(parts, axis=1) == 1).all()


def test_partition_round_robin_strides():
    parts = sgd.partition_indices(64, 4, "round_robin")
    assert (np.diff(parts, axis=1) == 4).all()
    assert sorted(parts.reshape(-1).tolist()) == list(range(64))


def test_partition_rep_k_halo():
    parts = sgd.partition_indices(64, 4, "chunk", rep_k=3)
    assert parts.shape == (4, 19)
    # halo of replica r = first 3 examples of replica (r+1) % 4
    for r in range(4):
        np.testing.assert_array_equal(
            parts[r, -3:], parts[(r + 1) % 4, :3])


def test_merge_replicas_mean():
    W = jnp.asarray(np.arange(12, dtype=np.float32).reshape(4, 3))
    M = sgd.merge_replicas(W)
    assert M.shape == W.shape
    np.testing.assert_allclose(M[0], W.mean(0))
    np.testing.assert_allclose(M, jnp.broadcast_to(W.mean(0), W.shape))


@pytest.fixture(scope="module")
def ds():
    return synthetic.make_dense("toy", 512, 16, seed=2)


def test_paper_claim_more_replicas_worse_statistical_efficiency(ds):
    """Paper §5.2.2: 'the more replicas, the lower the statistical
    efficiency' — fewer merges of more-diverged models learn less per epoch."""
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    prob = glm.GLMProblem("lr", X, y, 5e-3)
    losses = {}
    for r in (2, 16):
        res = sgd.run(prob, sgd.AsyncLocalSGD(replicas=r, local_batch=8), 6)
        losses[r] = res.losses[-1]
    assert losses[16] >= losses[2] * 0.999, losses


def test_paper_claim_rep_k_improves_statistical_efficiency(ds):
    """Paper §5.2.3: k-wise replication extracts more information per pass."""
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    prob = glm.GLMProblem("lr", X, y, 5e-3)
    res0 = sgd.run(prob, sgd.AsyncLocalSGD(replicas=8, local_batch=4,
                                           rep_k=0), 6)
    resk = sgd.run(prob, sgd.AsyncLocalSGD(replicas=8, local_batch=4,
                                           rep_k=16), 6)
    assert resk.losses[-1] <= res0.losses[-1] * 1.001


def test_access_path_changes_assignment_not_semantics(ds):
    """row-rr vs row-ch assign different examples but both converge."""
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    prob = glm.GLMProblem("lr", X, y, 5e-3)
    for access in ("chunk", "round_robin"):
        res = sgd.run(prob, sgd.AsyncLocalSGD(replicas=4, local_batch=8,
                                              access=access), 6)
        assert res.losses[-1] < res.losses[0]
