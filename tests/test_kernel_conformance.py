"""Backend conformance harness for the kernel dispatch layer.

Every registered kernel family runs against its pure-jnp reference oracle
on every backend available on this host (CPU CI: ``pallas-interpret`` and
``reference``; TPU adds ``pallas-tpu``), in fp32 and bf16, for both GLM
losses and both dense and sparse data.  Future kernel PRs must keep this
suite green — it is the executable contract of DESIGN.md §3.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.kernels  # noqa: F401  — registers all families
from repro.kernels import common
from repro.kernels.flash_attn import flash_attention
from repro.kernels.flash_attn.ref import attention_ref
from repro.kernels.glm_grad import glm_grad
from repro.kernels.glm_grad.ref import glm_grad_ref
from repro.kernels.glm_sgd import glm_sgd_epoch
from repro.kernels.glm_sgd.ref import glm_sgd_epoch_ref
from repro.kernels.glm_sgd_sparse import ell_sgd_epoch
from repro.kernels.glm_sgd_sparse.ref import ell_sgd_epoch_ref
from repro.kernels.glm_score import glm_score
from repro.kernels.glm_score.ref import glm_score_ref
from repro.kernels.glm_sparse import ell_glm_grad
from repro.kernels.glm_sparse.ref import ell_glm_grad_ref

FAMILIES = ("flash_attn", "glm_grad", "glm_score", "glm_sgd",
            "glm_sgd_sparse", "glm_sparse")
DTYPES = (jnp.float32, jnp.bfloat16)
TASKS = ("lr", "svm")


def _f32(*arrays):
    return tuple(a.astype(jnp.float32) for a in arrays)


# ---------------------------------------------------------------------------
# Registry invariants
# ---------------------------------------------------------------------------


def test_all_families_registered_with_all_backends():
    assert set(common.registered_kernels()) >= set(FAMILIES)
    for fam in FAMILIES:
        assert common.backends_for(fam) == common.BACKEND_ORDER, fam


def test_host_availability_excludes_pallas_tpu_off_tpu():
    for fam in FAMILIES:
        avail = common.available_backends(fam)
        assert common.REFERENCE in avail
        assert common.PALLAS_INTERPRET in avail
        assert (common.PALLAS_TPU in avail) == common.on_tpu()


def test_resolve_backend_precedence(monkeypatch):
    monkeypatch.setenv(common.ENV_BACKEND, common.REFERENCE)
    # env var overrides auto ...
    assert common.resolve_backend("glm_grad") == common.REFERENCE
    # ... but explicit call-site forcing beats the env var, whether via
    # backend= or the legacy interpret= flag
    assert (common.resolve_backend("glm_grad", backend=common.PALLAS_INTERPRET)
            == common.PALLAS_INTERPRET)
    assert (common.resolve_backend("glm_grad", interpret=True)
            == common.PALLAS_INTERPRET)


def test_resolve_backend_env_override_applies_to_calls(monkeypatch, glm_data):
    X, y, w = glm_data(16, 8)
    monkeypatch.setenv(common.ENV_BACKEND, common.REFERENCE)
    out = glm_grad("lr", w, X, y)
    np.testing.assert_allclose(out, glm_grad_ref("lr", w, X, y),
                               rtol=1e-6, atol=1e-6)


def test_resolve_backend_legacy_interpret_flag():
    assert (common.resolve_backend("glm_grad", interpret=True)
            == common.PALLAS_INTERPRET)
    if not common.on_tpu():
        with pytest.raises(RuntimeError, match="needs a TPU host"):
            common.resolve_backend("glm_grad", interpret=False)


def test_resolve_backend_rejects_unknown():
    with pytest.raises(KeyError):
        common.resolve_backend("no_such_kernel")
    with pytest.raises(ValueError, match="not registered"):
        common.resolve_backend("glm_grad", backend="cuda")


def test_resolve_backend_env_unregistered_name_errors(monkeypatch):
    """A bad REPRO_KERNEL_BACKEND value fails loudly, not silently."""
    monkeypatch.setenv(common.ENV_BACKEND, "cuda")
    with pytest.raises(ValueError, match="not registered"):
        common.resolve_backend("glm_grad")


def test_resolve_backend_forced_tpu_off_tpu_errors():
    if common.on_tpu():
        pytest.skip("forcing pallas-tpu is legal on a TPU host")
    with pytest.raises(RuntimeError, match="needs a TPU host"):
        common.resolve_backend("glm_grad", backend=common.PALLAS_TPU)


def test_resolve_backend_call_site_beats_env_beats_auto(monkeypatch):
    """Full precedence chain on one kernel: auto -> env -> call site."""
    monkeypatch.delenv(common.ENV_BACKEND, raising=False)
    auto = common.resolve_backend("glm_grad")
    assert auto == common.available_backends("glm_grad")[0]
    monkeypatch.setenv(common.ENV_BACKEND, common.REFERENCE)
    assert common.resolve_backend("glm_grad") == common.REFERENCE
    assert (common.resolve_backend("glm_grad",
                                   backend=common.PALLAS_INTERPRET)
            == common.PALLAS_INTERPRET)


def test_caps_reject_sparse_calls_on_dense_only_impls():
    dense_only = common.Caps()
    assert dense_only.supports({"dtype": "float32"})
    assert not dense_only.supports({"dtype": "float32", "sparse": True})
    assert common.Caps(sparse=True).supports({"sparse": True})


def test_caps_route_huge_sparse_problem_to_reference():
    info = {"dtype": "float32", "sparse": True, "n": 10_000, "d": 1_000_000}
    assert common.resolve_backend("glm_sparse", info=info) == common.REFERENCE
    info["d"] = 20_958
    assert (common.resolve_backend("glm_sparse", info=info)
            != common.REFERENCE)


def test_glm_sparse_legacy_interpret_respects_budget(monkeypatch, ell_data):
    """interpret= picks the Pallas flavor in budget, but never forces the
    one-hot kernel onto problems the VMEM/FLOP budget excludes."""
    seen = []
    real = common.dispatch

    def spy(kernel, *a, **kw):
        seen.append(kw.get("backend"))
        return real(kernel, *a, **kw)

    monkeypatch.setattr(common, "dispatch", spy)
    values, indices, y, w = ell_data(32, 256, 4)
    ell_glm_grad("lr", w, values, indices, y, interpret=True, d_block=128)
    assert seen[-1] == common.PALLAS_INTERPRET
    big_w = jnp.zeros(40_000)  # d > _MAX_D_PALLAS
    ell_glm_grad("lr", big_w, values, indices, y, interpret=True)
    assert seen[-1] == common.REFERENCE  # caps route the call to reference


def test_caps_route_odd_head_dim_to_reference(attn_data):
    q, k, v = attn_data(1, 2, 2, 16, 16, 12)  # hd=12: not sublane-aligned
    assert (common.resolve_backend("flash_attn",
                                   info={"dtype": "float32", "head_dim": 12})
            == common.REFERENCE)
    out = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, attention_ref(q, k, v, causal=True),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# glm_grad: dense sum-gradient
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", common.available_backends("glm_grad"))
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
@pytest.mark.parametrize("task", TASKS)
def test_glm_grad_conformance(backend, dtype, task, glm_data):
    X, y, w = glm_data(96, 50, dtype)
    ref = glm_grad_ref(task, *_f32(w, X, y))
    out = glm_grad(task, w, X, y, backend=backend, block_rows=16)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=2e-3)


@pytest.mark.parametrize("backend", common.available_backends("glm_grad"))
def test_glm_grad_col_layout_conformance(backend, glm_data):
    X, y, w = glm_data(64, 40)
    ref = glm_grad_ref("lr", w, X, y)
    out = glm_grad("lr", w, X, y, backend=backend, layout="col", block_rows=16)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# glm_sgd: fused epoch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", common.available_backends("glm_sgd"))
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
@pytest.mark.parametrize("task", TASKS)
@pytest.mark.parametrize("mb", [1, 4])
def test_glm_sgd_conformance(backend, dtype, task, mb, glm_data):
    X, y, w = glm_data(32, 40, dtype)
    ref = glm_sgd_epoch_ref(task, *_f32(w, X, y), 0.02, mb)
    out = glm_sgd_epoch(task, w, X, y, step=0.02, micro_batch=mb,
                        backend=backend)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_glm_sgd_caps_route_ragged_n_to_reference(glm_data):
    """Auto dispatch falls through to the ragged-tail oracle when
    micro_batch does not divide n; forcing a Pallas flavor raises."""
    X, y, w = glm_data(30, 16)  # 30 % 4 != 0
    info = {"dtype": "float32", "n": 30, "micro_batch": 4}
    assert common.resolve_backend("glm_sgd", info=info) == common.REFERENCE
    ref = glm_sgd_epoch_ref("lr", w, X, y, 0.02, 4)
    out = glm_sgd_epoch("lr", w, X, y, step=0.02, micro_batch=4)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError, match="micro_batch"):
        glm_sgd_epoch("lr", w, X, y, step=0.02, micro_batch=4,
                      backend=common.PALLAS_INTERPRET)


# ---------------------------------------------------------------------------
# glm_sgd_sparse: fused ELL epoch (gradient + update in one launch)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "backend",
    common.available_backends("glm_sgd_sparse", info={"sparse": True}))
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
@pytest.mark.parametrize("task", TASKS)
@pytest.mark.parametrize("mb", [1, 4])
def test_glm_sgd_sparse_conformance(backend, dtype, task, mb, ell_data):
    values, indices, y, w = ell_data(32, 200, 6, dtype)
    ref = ell_sgd_epoch_ref(task, *_f32(w, values), indices,
                            y.astype(jnp.float32), 0.05, mb)
    out = ell_sgd_epoch(task, w, values, indices, y, step=0.05,
                        micro_batch=mb, backend=backend)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=2e-3)


def test_glm_sgd_sparse_caps_route_ragged_n_to_reference(ell_data):
    values, indices, y, w = ell_data(30, 200, 6)  # 30 % 8 != 0
    info = {"dtype": "float32", "sparse": True, "n": 30, "d": 200, "k": 6,
            "micro_batch": 8}
    assert (common.resolve_backend("glm_sgd_sparse", info=info)
            == common.REFERENCE)
    ref = ell_sgd_epoch_ref("lr", w, values, indices, y, 0.05, 8)
    out = ell_sgd_epoch("lr", w, values, indices, y, step=0.05, micro_batch=8)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError, match="micro_batch"):
        ell_sgd_epoch("lr", w, values, indices, y, step=0.05, micro_batch=8,
                      backend=common.PALLAS_INTERPRET)


def test_glm_sgd_sparse_caps_route_over_budget_to_reference():
    """A one-hot too large for VMEM routes to the oracle automatically."""
    from repro.kernels.glm_sgd_sparse.ops import onehot_budget_ok

    assert onehot_budget_ok(d=4096, k=8, micro_batch=16)
    assert not onehot_budget_ok(d=1_000_000, k=8, micro_batch=16)
    info = {"dtype": "float32", "sparse": True, "n": 64, "d": 1_000_000,
            "k": 8, "micro_batch": 16}
    assert (common.resolve_backend("glm_sgd_sparse", info=info)
            == common.REFERENCE)


# ---------------------------------------------------------------------------
# glm_sparse: ELL sum-gradient
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", common.available_backends("glm_sparse"))
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
@pytest.mark.parametrize("task", TASKS)
def test_glm_sparse_conformance(backend, dtype, task, ell_data):
    values, indices, y, w = ell_data(64, 384, 8, dtype)
    ref = ell_glm_grad_ref(task, *_f32(w, values), indices, y.astype(jnp.float32))
    out = ell_glm_grad(task, w, values, indices, y, backend=backend,
                       block_rows=8, d_block=128)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# glm_score: fused ELL scoring (gather-dot + task link, serving path)
# ---------------------------------------------------------------------------


def _dense_scores(task, w, values, indices):
    """Dense oracle: scatter the ELL rows into a dense X, score X @ w.

    Independent of the lax.scan reference — a shared gather bug in both
    paths cannot cancel out here.
    """
    values = np.asarray(values, np.float32)
    indices = np.asarray(indices, np.int64)
    w = np.asarray(w, np.float32)
    X = np.zeros((values.shape[0], w.shape[0]), np.float32)
    for i in range(values.shape[0]):
        np.add.at(X[i], indices[i], values[i])   # duplicates accumulate
    from repro.core.glm import LINKS

    return np.asarray(LINKS[task](jnp.asarray(X @ w)), np.float32)


@pytest.mark.parametrize(
    "backend",
    common.available_backends("glm_score", info={"sparse": True}))
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
@pytest.mark.parametrize("task", TASKS)
def test_glm_score_conformance(backend, dtype, task, ell_data):
    values, indices, _, w = ell_data(48, 384, 8, dtype)
    ref = _dense_scores(task, *_f32(w, values), indices)
    out = glm_score(task, w, values, indices, backend=backend, block_rows=8)
    assert out.dtype == jnp.float32
    assert out.shape == (48,)
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=2e-3)
    np.testing.assert_allclose(glm_score_ref(task, *_f32(w, values), indices),
                               ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "backend",
    common.available_backends("glm_score", info={"sparse": True}))
def test_glm_score_ragged_rows_conformance(backend, ell_data):
    """n not divisible by block_rows: filler rows are sliced off."""
    values, indices, _, w = ell_data(30, 200, 6)
    ref = _dense_scores("lr", w, values, indices)
    out = glm_score("lr", w, values, indices, backend=backend, block_rows=8)
    assert out.shape == (30,)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=2e-3)


@pytest.mark.parametrize(
    "backend",
    common.available_backends("glm_score", info={"sparse": True}))
def test_glm_score_padding_rows_contribute_exactly_zero(backend):
    """All-padding ELL rows (value 0, index 0) have margin *exactly* 0.0:
    SVM scores exactly 0.0, LR exactly sigmoid(0) = 0.5 — bit-exact, not
    allclose, since the serving engine pads every batch with such rows."""
    d, k = 256, 4
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 1, d), jnp.float32)  # w[0] != 0
    values = jnp.zeros((8, k), jnp.float32)
    values = values.at[0].set(jnp.arange(1.0, k + 1))  # one real row
    indices = jnp.zeros((8, k), jnp.int32)
    indices = indices.at[0].set(jnp.arange(1, k + 1))
    svm = np.asarray(glm_score("svm", w, values, indices, backend=backend,
                               block_rows=8))
    lr = np.asarray(glm_score("lr", w, values, indices, backend=backend,
                              block_rows=8))
    assert (svm[1:] == 0.0).all(), svm
    assert (lr[1:] == 0.5).all(), lr
    assert svm[0] != 0.0 and lr[0] != 0.5  # the real row actually scored


def test_glm_score_caps_route_over_budget_to_reference():
    """A one-hot too large for VMEM routes scoring to the oracle."""
    from repro.kernels.glm_score.ops import onehot_budget_ok

    assert onehot_budget_ok(d=4096, k=8, block_rows=8)
    assert not onehot_budget_ok(d=1_000_000, k=8, block_rows=8)
    info = {"dtype": "float32", "sparse": True, "n": 32, "d": 1_000_000,
            "k": 8}
    assert common.resolve_backend("glm_score", info=info) == common.REFERENCE
    small = dict(info, d=4096)
    assert common.resolve_backend("glm_score", info=small) != common.REFERENCE


@pytest.mark.parametrize("task", TASKS)
def test_glm_score_backends_agree_pairwise(task, ell_data):
    values, indices, _, w = ell_data(32, 256, 6)
    outs = [np.asarray(glm_score(task, w, values, indices, backend=b,
                                 block_rows=8))
            for b in common.available_backends("glm_score",
                                               info={"sparse": True})]
    for other in outs[1:]:
        np.testing.assert_allclose(outs[0], other, rtol=1e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# flash_attn: blocked attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", common.available_backends("flash_attn"))
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
@pytest.mark.parametrize("causal,window", [(True, None), (True, 16),
                                           (False, None)])
def test_flash_attn_conformance(backend, dtype, causal, window, attn_data):
    q, k, v = attn_data(2, 4, 2, 64, 64, 32, dtype)
    kr = jnp.repeat(k, 2, axis=1)
    vr = jnp.repeat(v, 2, axis=1)
    ref = attention_ref(*_f32(q, kr, vr), causal=causal, window=window)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          backend=backend, block_q=16, block_k=16)
    loose = jnp.dtype(dtype) == jnp.bfloat16
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref,
        rtol=0.05 if loose else 1e-3, atol=0.05 if loose else 2e-3)


@pytest.mark.parametrize("backend", common.available_backends("flash_attn"))
def test_flash_attn_decode_conformance(backend, attn_data):
    q, k, v = attn_data(2, 4, 2, 1, 64, 16)
    ref = attention_ref(q, jnp.repeat(k, 2, 1), jnp.repeat(v, 2, 1),
                        causal=True)
    out = flash_attention(q, k, v, causal=True, backend=backend,
                          block_q=1, block_k=16)
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Cross-backend agreement: the dispatch paths agree with each other, not
# just with the oracle (catches oracle-shaped bugs shared by one path).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("task", TASKS)
def test_glm_grad_backends_agree_pairwise(task, glm_data):
    X, y, w = glm_data(48, 30)
    outs = [np.asarray(glm_grad(task, w, X, y, backend=b, block_rows=16))
            for b in common.available_backends("glm_grad")]
    for other in outs[1:]:
        np.testing.assert_allclose(outs[0], other, rtol=1e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# Engine wiring: AsyncLocalSGD.kernel_backend routes replica epochs through
# the registry (dense -> glm_sgd vmapped over replicas, sparse -> glm_sparse)
# and reproduces the pure-XLA engine path on every available backend.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", common.available_backends("glm_sgd"))
@pytest.mark.parametrize("local_batch", [1, 4])
def test_async_engine_kernel_backend_dense(backend, local_batch, glm_data):
    from repro.core import glm, sgd

    X, y, _ = glm_data(64, 16)
    prob = glm.GLMProblem("lr", X, y, 5e-3)
    strat = sgd.AsyncLocalSGD(replicas=4, local_batch=local_batch)
    base = sgd.run(prob, strat, 3, record_time=False)
    routed = sgd.run(
        prob, dataclasses.replace(strat, kernel_backend=backend), 3,
        record_time=False)
    np.testing.assert_allclose(routed.losses, base.losses,
                               rtol=1e-4, atol=1e-4)


def test_async_engine_kernel_backend_dense_rejects_ragged_partition(glm_data):
    """local_batch must divide the partition size (n//R + rep_k)."""
    from repro.core import glm, sgd

    X, y, _ = glm_data(64, 16)
    prob = glm.GLMProblem("lr", X, y, 5e-3)
    strat = sgd.AsyncLocalSGD(replicas=4, local_batch=5,
                              kernel_backend=common.REFERENCE)
    with pytest.raises(ValueError, match="divide the"):
        sgd.make_epoch_fn(prob, strat)


@pytest.mark.parametrize(
    "backend",
    common.available_backends("glm_sparse", info={"sparse": True, "n": 64,
                                                  "d": 128}))
def test_async_engine_kernel_backend_sparse(backend):
    """Sparse replica epochs route through glm_sparse when the local update
    is full-partition (sum-gradient kernel) and through the fused
    glm_sgd_sparse epoch for mini-batch local updates; a local_batch that
    does not divide the partition must refuse rather than silently fall
    back."""
    import jax.numpy as jnp

    from repro.core import sgd
    from repro.data import synthetic

    sp = synthetic.make_sparse("sp-async", 64, 128, 5.0, 8, seed=4)
    per = 64 // 4
    prob = ("lr", sp.ell, jnp.asarray(sp.y), 0.05)
    for local_batch in (per, 4):
        base = sgd.run(
            prob, sgd.AsyncLocalSGD(replicas=4, local_batch=local_batch), 3,
            sparse_data=True, record_time=False)
        routed = sgd.run(
            prob, sgd.AsyncLocalSGD(replicas=4, local_batch=local_batch,
                                    kernel_backend=backend), 3,
            sparse_data=True, record_time=False)
        np.testing.assert_allclose(routed.losses, base.losses,
                                   rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError, match="divide the"):
        sgd.make_epoch_fn(
            prob, sgd.AsyncLocalSGD(replicas=4, local_batch=5,
                                    kernel_backend=backend),
            sparse_data=True)


def test_async_strategy_name_includes_backend():
    from repro.core import sgd

    plain = sgd.AsyncLocalSGD(replicas=4)
    routed = sgd.AsyncLocalSGD(replicas=4, kernel_backend=common.REFERENCE)
    assert plain.name + f"[{common.REFERENCE}]" == routed.name
