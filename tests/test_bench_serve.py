"""Tests for the serving trajectory producer, its store, and the gate."""
import json

from repro.study import claims
from repro.study.store import ServeBenchStore


# ---------------------------------------------------------------------------
# claims.check_bench_serve: conformance + latency/throughput gate
# ---------------------------------------------------------------------------


def _row(label="serve/lr/d512-k4/batch8", p50=1e-4, p99=2e-4, rps=1e4,
         match=True, baseline=None):
    return {"label": label, "p50_s": p50, "p99_s": p99, "rps": rps,
            "pallas_match": match, "baseline_p50_s": baseline}


def test_gate_clean_rows_pass():
    assert claims.check_bench_serve([_row(), _row(match=None)]) == []


def test_gate_flags_oracle_mismatch():
    bad = claims.check_bench_serve([_row(match=False)])
    assert len(bad) == 1 and "mismatch" in bad[0]


def test_gate_flags_nonpositive_throughput():
    bad = claims.check_bench_serve([_row(rps=0.0)])
    assert len(bad) == 1 and "throughput" in bad[0]


def test_gate_flags_inverted_quantiles():
    bad = claims.check_bench_serve([_row(p50=2e-4, p99=1e-4)])
    assert len(bad) == 1 and "p99 < p50" in bad[0]


def test_gate_flags_latency_regression_over_tolerance():
    tol = claims.SERVE_REGRESSION_TOL
    ok = _row(p50=1e-4 * (1 + tol) * 0.99, p99=1.0, baseline=1e-4)
    slow = _row(p50=1e-4 * (1 + tol) * 1.05, p99=1.0, baseline=1e-4)
    assert claims.check_bench_serve([ok]) == []
    bad = claims.check_bench_serve([slow])
    assert len(bad) == 1 and "regressed" in bad[0]


def test_gate_ignores_missing_baseline():
    # cross-host / first-run points have no comparable committed entry
    assert claims.check_bench_serve([_row(p50=100.0, p99=200.0,
                                          baseline=None)]) == []


def test_gate_rejects_fully_unchecked_run():
    """Same vacuous-green guard as the kernel gate: a run where no Pallas
    flavor of glm_score was checked must not validate as green."""
    rows = [_row(match=None), _row(label="b", match=None)]
    bad = claims.check_bench_serve(rows)
    assert len(bad) == 1 and "unchecked" in bad[0]
    assert claims.check_bench_serve(rows[:1] + [_row()]) == []


# ---------------------------------------------------------------------------
# ServeBenchStore determinism
# ---------------------------------------------------------------------------


def test_serve_store_snapshot_sorted_and_deterministic(tmp_path):
    s = ServeBenchStore(tmp_path / "BENCH_serve.json",
                        jsonl_path=tmp_path / "runs.jsonl")
    s.record_entry("b/label", {"p50_s": 2.0})
    s.record_entry("a/label", {"p50_s": 1.0}, cached=True)
    s.record_event("serve_timing", label="a/label", wall_s=0.1)
    snap = s.snapshot()
    assert list(snap["entries"]) == ["a/label", "b/label"]
    assert "ts" not in json.dumps(snap)
    assert "serve_timing" not in json.dumps(snap)  # events never enter it
    p = s.write()
    first = p.read_bytes()
    s.write()
    assert p.read_bytes() == first  # snapshot has no run-varying fields
    assert ServeBenchStore.load(p) == snap
    # run-variance (events + summary lines) goes to the sidecar only
    lines = [json.loads(l) for l in (tmp_path / "runs.jsonl").open()]
    assert len(lines) == 3 and all("ts" in l for l in lines)
    assert lines[0]["event"] == "serve_timing"
    assert lines[1]["n_entries"] == 2 and lines[1]["n_cached"] == 1


def test_serve_store_default_path_is_committed_trajectory():
    assert ServeBenchStore().json_path.name == "BENCH_serve.json"


# ---------------------------------------------------------------------------
# Producer end-to-end (micro shapes): trajectory points + reproducibility
# ---------------------------------------------------------------------------


TINY_PROFILES = {
    "ci": dict(n_requests=24, d=128, batches=(4, 8), ks=(2, 4)),
}


def test_producer_trajectory_and_byte_reproducibility(tmp_path, monkeypatch):
    from benchmarks import bench_serve, common

    monkeypatch.setattr(bench_serve, "PROFILES", TINY_PROFILES)
    monkeypatch.setattr(common, "RESULTS_DIR", tmp_path / "res")
    out = tmp_path / "BENCH_serve.json"

    rows = bench_serve.run("ci", out_json=str(out))
    data = json.loads(out.read_text())
    assert len(data["entries"]) == 4  # 2 batches x 2 sparsities
    for e in data["entries"].values():
        assert e["kernel"] == "glm_score"
        assert e["p50_s"] > 0 and e["p99_s"] >= e["p50_s"] and e["rps"] > 0
        assert e["pallas_match"] is True  # interpret flavor checked on CPU
        assert e["checked_backends"]      # at least one non-reference flavor
        assert e["roofline"]["bound"] in ("compute", "memory")
        assert {"host", "device_kind", "backend", "engine"} <= set(e)
    # cold run: committed file absent -> no baselines, gate clean
    assert all(r["baseline_p50_s"] is None for r in rows)
    assert claims.check_bench_serve(rows) == []

    first = out.read_bytes()
    rows2 = bench_serve.run("ci", out_json=str(out))
    assert out.read_bytes() == first  # warm re-run is byte-identical
    # warm run gates against the (now committed) same-host trajectory
    assert all(r["baseline_p50_s"] == r["p50_s"] for r in rows2)
    assert claims.check_bench_serve(rows2) == []


def test_producer_threaded_consumers_mode(tmp_path, monkeypatch):
    """--consumers N: the threaded driver loses nothing, reports sane
    stats, and its points are /cN-labelled so they never gate against
    the committed single-consumer trajectory."""
    from benchmarks import bench_serve, common

    monkeypatch.setattr(bench_serve, "PROFILES", {
        "ci": dict(n_requests=24, d=128, batches=(8,), ks=(4,))})
    monkeypatch.setattr(common, "RESULTS_DIR", tmp_path / "res")
    out = tmp_path / "BENCH_serve.json"

    rows = bench_serve.run("ci", out_json=str(out), consumers=3)
    assert len(rows) == 1
    r = rows[0]
    assert r["label"].endswith("/batch8/c3") and r["consumers"] == 3
    assert r["p50_s"] > 0 and r["p99_s"] >= r["p50_s"] and r["rps"] > 0
    assert claims.check_bench_serve(rows) == []
    # the /cN label namespace is disjoint from the single-consumer one
    rows1 = bench_serve.run("ci", out_json=str(out), consumers=1)
    assert rows1[0]["label"] == r["label"][: -len("/c3")]
    assert rows1[0]["baseline_p50_s"] is None   # no cross-mode gating
    import pytest as _pytest
    with _pytest.raises(ValueError, match="consumers"):
        bench_serve.run("ci", out_json=str(out), consumers=0)
