"""Roofline HLO parser: trip-count multipliers, dot FLOPs, collective
bytes, memory model — against hand-crafted HLO and a real compiled module."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import hlo

MINI_HLO = """
HloModule test

%body.1 (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(f32[8,16]{1,0} %g1, f32[16,16]{1,0} %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups=[16,16]<=[256], to_apply=%add.0
  ROOT %t = (s32[], f32[8,16]) tuple(%g0, %ar)
}

%cond.1 (arg: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (x: f32[8,16]) -> f32[8,16] {
  %x = f32[8,16]{1,0} parameter(0)
  %init = (s32[], f32[8,16]) tuple(s32[] constant(0), %x)
  %wh = (s32[], f32[8,16]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_shape_bytes():
    assert hlo.shape_bytes("f32[8,16]{1,0}") == 512
    assert hlo.shape_bytes("bf16[4,4]") == 32
    assert hlo.shape_bytes("(f32[2,2], s32[3])") == 28
    assert hlo.shape_bytes("pred[10]") == 10


def test_trip_count_multiplier_and_dot_flops():
    comps = hlo.parse_computations(MINI_HLO)
    assert set(comps) >= {"body.1", "cond.1", "main"}
    mult = hlo.compute_multipliers(comps, "main")
    assert mult["body.1"] == 10.0          # known_trip_count applied
    flops, by_dt = hlo.dot_flops(comps, mult)
    # dot: 2 * (8*16 out) * 16 contract = 4096 per trip, x10 trips
    assert flops == pytest.approx(40960.0)
    assert by_dt == {"f32": pytest.approx(40960.0)}


def test_collective_ring_model():
    comps = hlo.parse_computations(MINI_HLO)
    mult = hlo.compute_multipliers(comps, "main")
    total, by_kind = hlo.collective_bytes(comps, mult)
    # all-reduce of 512 bytes in groups of 16: 2*(15/16)*512 per trip, x10
    assert total == pytest.approx(2 * 15 / 16 * 512 * 10)
    assert "all-reduce" in by_kind


def test_group_size_parsing():
    assert hlo._group_size("replica_groups=[16,16]<=[256]") == 16
    assert hlo._group_size("replica_groups=[64,4]<=[256]") == 4
    assert hlo._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4


def test_real_compiled_module_roundtrip():
    """Parse an actually-compiled scan module; trip-aware FLOPs must exceed
    cost_analysis (which counts loop bodies once) by ~the trip count."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    a = hlo.analyze(compiled.as_text())
    ca_flops = hlo.cost_analysis_dict(compiled)["flops"]
    per_iter = 2 * 64 * 64 * 64
    assert a.dot_flops == pytest.approx(10 * per_iter, rel=0.01)
    assert ca_flops == pytest.approx(per_iter, rel=0.1)   # the XLA gotcha
    assert a.max_trip == 10


def test_memory_model_inplace_semantics():
    """DUS counts only the update slice, not the aliased big buffer."""
    text = """
ENTRY %m (b: f32[1000,64], u: f32[1,64]) -> f32[1000,64] {
  %b = f32[1000,64]{1,0} parameter(0)
  %u = f32[1,64]{1,0} parameter(1)
  %z = s32[] constant(0)
  ROOT %d = f32[1000,64]{1,0} dynamic-update-slice(%b, %u, %z, %z)
}
"""
    comps = hlo.parse_computations(text)
    mult = hlo.compute_multipliers(comps, "m")
    mem = hlo.memory_bytes(comps, mult, set())
    # update slice read+write (+ the two s32 index scalars), not the 256 KB
    # aliased buffer
    assert mem == pytest.approx(2 * (64 * 4 + 2 * 4))


def test_glm_task_configs():
    from repro.configs.glm import GLM_CONFIGS, get_glm
    assert len(GLM_CONFIGS) == 10            # 5 datasets x 2 tasks
    c = get_glm("w8a-lr")
    assert c.async_rep_k == 10 and c.async_access == "round_robin"
    strat = c.async_strategy()
    assert strat.rep_k == 10
