"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra not installed "
                    "(pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import glm, sgd, sparse
from repro.optim import compress

SETTINGS = dict(max_examples=25, deadline=None)


@given(n=st.integers(8, 200), r=st.integers(1, 8),
       access=st.sampled_from(["chunk", "round_robin"]),
       rep_k=st.integers(0, 4))
@settings(**SETTINGS)
def test_partition_indices_exact_cover(n, r, access, rep_k):
    """Every replica gets per+rep_k examples; the non-halo part covers
    [0, per*r) exactly once; all indices in range."""
    if r > n:
        return
    parts = sgd.partition_indices(n, r, access, rep_k)
    per = n // r
    assert parts.shape == (r, per + rep_k)
    base = parts[:, :per].reshape(-1)
    assert sorted(base.tolist()) == list(range(per * r))
    assert parts.min() >= 0 and parts.max() < per * r


@given(r=st.integers(1, 6), d=st.integers(1, 16))
@settings(**SETTINGS)
def test_merge_is_idempotent_and_mean_preserving(r, d):
    rng = np.random.default_rng(r * 100 + d)
    W = jnp.asarray(rng.normal(0, 1, (r, d)).astype(np.float32))
    M = sgd.merge_replicas(W)
    np.testing.assert_allclose(np.asarray(M).mean(0), np.asarray(W).mean(0),
                               rtol=1e-5, atol=1e-6)
    M2 = sgd.merge_replicas(M)
    np.testing.assert_allclose(M, M2, rtol=1e-6, atol=1e-7)


@given(n=st.integers(1, 40), d=st.integers(2, 64), seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_ell_matches_dense_grad(n, d, seed):
    """ELL gradient == dense gradient for arbitrary sparsity patterns."""
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    X[rng.random((n, d)) < 0.8] = 0.0
    y = np.where(rng.random(n) < 0.5, -1.0, 1.0).astype(np.float32)
    w = rng.normal(0, 0.5, d).astype(np.float32)
    m = sparse.from_dense(X)
    gs = sparse.grad("lr", m, jnp.asarray(y), jnp.asarray(w))
    gd = glm.grad_fused("lr", jnp.asarray(w), jnp.asarray(X), jnp.asarray(y))
    np.testing.assert_allclose(gs, gd, rtol=1e-3, atol=1e-3)


@given(seed=st.integers(0, 99), scale=st.floats(1e-3, 1e3),
       n=st.integers(1, 2000))
@settings(**SETTINGS)
def test_quantize_dequantize_bounded_error(seed, scale, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((scale * rng.normal(0, 1, (n,))).astype(np.float32))
    q, s = compress.quantize_leaf(x)
    deq = compress.dequantize_leaf(q, s, x)
    max_scale = float(jnp.max(s))
    assert float(jnp.max(jnp.abs(deq - x))) <= 0.5 * max_scale + 1e-6


@given(seed=st.integers(0, 50), b=st.integers(1, 3),
       s_pow=st.integers(3, 6), causal=st.booleans())
@settings(max_examples=10, deadline=None)
def test_chunked_attention_equals_reference(seed, b, s_pow, causal):
    from repro.nn import attention
    from repro.kernels.flash_attn.ref import attention_ref
    rng = np.random.default_rng(seed)
    S, H, hd = 2 ** s_pow, 2, 8
    q = jnp.asarray(rng.normal(0, 1, (b, H, S, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (b, H, S, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (b, H, S, hd)).astype(np.float32))
    ref = attention_ref(q, k, v, causal=causal)
    out = attention.chunked_attention(q, k, v, causal=causal, chunk_q=8)
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)


@given(seed=st.integers(0, 30), mb=st.sampled_from([1, 2, 8]))
@settings(max_examples=10, deadline=None)
def test_fused_sgd_kernel_matches_ref_property(seed, mb):
    from repro.kernels.glm_sgd import glm_sgd_epoch
    from repro.kernels.glm_sgd.ref import glm_sgd_epoch_ref
    rng = np.random.default_rng(seed)
    n, d = 16, 20
    X = jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32))
    y = jnp.asarray(np.where(rng.random(n) < 0.5, -1.0, 1.0).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.1, d).astype(np.float32))
    ref = glm_sgd_epoch_ref("lr", w, X, y, 0.05, mb)
    out = glm_sgd_epoch("lr", w, X, y, step=0.05, micro_batch=mb)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@given(k=st.integers(0, 8), r=st.integers(2, 8))
@settings(**SETTINGS)
def test_halo_preserves_base_partition(k, r):
    from repro.data.pipeline import shard_with_halo
    n = r * 16
    shards = shard_with_halo(n, r, k)
    for s in shards:
        assert len(s) == 16 + k
    base = np.concatenate([s[:16] for s in shards])
    assert sorted(base.tolist()) == list(range(n))
