"""Convergence methodology (§6.1): thresholds, step grid, run metrics."""
import math

import numpy as np
import pytest

from repro.core import convergence, sgd


# ---------------------------------------------------------------------------
# thresholds
# ---------------------------------------------------------------------------


def test_thresholds_positive_optimum():
    th = convergence.thresholds(10.0)
    assert th[0.10] == pytest.approx(11.0)
    assert th[0.01] == pytest.approx(10.1)
    # looser tolerance => easier (larger) target
    assert th[0.10] > th[0.05] > th[0.02] > th[0.01] > 10.0


def test_thresholds_negative_optimum():
    """'Within t of the optimum' must stay above a *negative* optimum."""
    th = convergence.thresholds(-2.0)
    assert th[0.10] == pytest.approx(-1.8)
    assert th[0.01] == pytest.approx(-1.98)
    for t, target in th.items():
        assert target > -2.0  # reachable: above the optimum
    assert th[0.10] > th[0.01]  # looser tolerance is still easier


def test_thresholds_zero_optimum():
    th = convergence.thresholds(0.0)
    assert all(v == 0.0 for v in th.values())


def test_thresholds_custom_tolerances():
    th = convergence.thresholds(4.0, (0.5,))
    assert th == {0.5: pytest.approx(6.0)}


# ---------------------------------------------------------------------------
# grid_step_sizes
# ---------------------------------------------------------------------------


def test_grid_step_sizes_default_bounds():
    grid = convergence.grid_step_sizes()
    assert grid[0] == pytest.approx(1e-6)
    assert grid[-1] == pytest.approx(1e2)
    assert len(grid) == 9  # one per decade, inclusive
    assert grid == sorted(grid)
    ratios = [b / a for a, b in zip(grid, grid[1:])]
    assert all(r == pytest.approx(10.0) for r in ratios)


def test_grid_step_sizes_custom_bounds():
    grid = convergence.grid_step_sizes(-2, 0)
    assert grid == pytest.approx([1e-2, 1e-1, 1.0])
    assert convergence.grid_step_sizes(0, 0) == pytest.approx([1.0])


# ---------------------------------------------------------------------------
# RunResult.epochs_to / time_to
# ---------------------------------------------------------------------------


def _result(losses, times):
    return sgd.RunResult(losses=np.asarray(losses, dtype=float),
                         epoch_times=np.asarray(times, dtype=float),
                         strategy="s", task="lr")


def test_epochs_and_time_to_monotone_curve():
    res = _result([1.0, 0.8, 0.6, 0.4], [0.1, 0.2, 0.3])
    assert res.epochs_to(0.6) == 2
    assert res.time_to(0.6) == pytest.approx(0.3)   # 0.1 + 0.2
    assert res.epochs_to(1.0) == 0 and res.time_to(1.0) == 0.0
    assert res.epochs_to(0.39) is None and res.time_to(0.39) is None


def test_epochs_to_oscillating_curve_takes_first_crossing():
    """An oscillating curve counts the *first* epoch at/below target, even
    if the loss later bounces back above it."""
    res = _result([1.0, 0.5, 0.9, 0.45, 0.7], [0.1, 0.1, 0.1, 0.1])
    assert res.epochs_to(0.5) == 1       # not 3: first crossing wins
    assert res.epochs_to(0.45) == 3      # reached only on the second dip
    assert res.time_to(0.45) == pytest.approx(0.3)
    assert res.epochs_to(0.2) is None


def test_time_per_epoch_is_mean():
    res = _result([1.0, 0.9], [0.2, 0.4])
    assert res.time_per_epoch == pytest.approx(0.3)


# ---------------------------------------------------------------------------
# rank_key (the §6.1 selection order)
# ---------------------------------------------------------------------------


def test_rank_key_orders_converged_before_stuck_before_diverged():
    fast = _result([1.0, 0.1], [0.1])
    slow = _result([1.0, 0.5, 0.1], [0.1, 0.5])
    stuck = _result([1.0, 0.9], [0.1])
    diverged = _result([1.0, float("nan")], [0.1])
    keys = [convergence.rank_key(r, target=0.2)
            for r in (fast, slow, stuck, diverged)]
    assert keys == sorted(keys)
    assert keys[-1] == (2, math.inf)


def test_rank_key_epochs_mode_ignores_wall_time():
    """by="epochs" ranks on statistical efficiency only — a slower-clock
    run with fewer epochs-to-target wins (deterministic advisor mode)."""
    few_slow = _result([1.0, 0.1], [10.0])
    many_fast = _result([1.0, 0.5, 0.1], [0.01, 0.01])
    by_time = sorted([many_fast, few_slow],
                     key=lambda r: convergence.rank_key(r, 0.2, by="time"))
    by_epochs = sorted([many_fast, few_slow],
                       key=lambda r: convergence.rank_key(r, 0.2, by="epochs"))
    assert by_time[0] is many_fast
    assert by_epochs[0] is few_slow


def test_optimal_loss_ignores_non_finite():
    a = _result([1.0, 0.5], [0.1])
    b = _result([1.0, float("inf"), float("nan")], [0.1, 0.1])
    assert convergence.optimal_loss([a, b]) == pytest.approx(0.5)
