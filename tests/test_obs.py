"""Observability: span tracer, metrics registry, export, report CLI.

The acceptance contract of the subsystem (ISSUE 7):
* disabled (the default) the tracer is a shared no-op — no files, no
  jit-lowering drift, bounded overhead on a tight loop;
* enabled, spans nest, round-trip through the JSONL file, and export to
  valid Chrome trace-event JSON the report CLI validates;
* metrics snapshots are deterministic and never enter ``BENCH_*.json``;
* store JSONL events are schema-stamped and validated on read;
* a traced 2-worker sweep leaves per-shard trace files that stitch into
  one timeline with the driver.
"""
import json
import time

import jax
import jax.numpy as jnp
import pytest

from repro.core import sgd
from repro.obs import export, metrics, report, trace
from repro.study import spec, store
from repro.study.runner import Runner
from repro.sweep import LocalProcessExecutor
from repro.utils.timing import median_time, time_stats


@pytest.fixture
def traced(tmp_path, monkeypatch):
    """Enable tracing into a temp dir; restore the disabled state after."""
    monkeypatch.setenv(trace.ENV_TRACE, "1")
    monkeypatch.setenv(trace.ENV_TRACE_DIR, str(tmp_path))
    monkeypatch.delenv(trace.ENV_TRACE_TAG, raising=False)
    trace.refresh()
    metrics.reset()
    yield tmp_path
    monkeypatch.delenv(trace.ENV_TRACE, raising=False)
    trace.refresh()
    metrics.reset()


# ---------------------------------------------------------------------------
# disabled fast path
# ---------------------------------------------------------------------------


def test_disabled_is_shared_noop_and_writes_nothing(tmp_path, monkeypatch):
    monkeypatch.delenv(trace.ENV_TRACE, raising=False)
    monkeypatch.setenv(trace.ENV_TRACE_DIR, str(tmp_path))
    trace.refresh()
    assert not trace.enabled()
    assert trace.current_path() is None
    assert trace.span("a.b", x=1) is trace.span("c.d")      # the singleton
    with trace.span("runner.trial", key="k"):
        trace.instant("kernel.caps_fallback", chosen="reference")
    assert list(tmp_path.iterdir()) == []                   # no I/O at all


def test_disabled_decorator_returns_function_unchanged():
    def f(x):
        return x + 1

    assert trace.span("study.tune")(f) is f


def test_disabled_overhead_is_bounded():
    t0 = time.perf_counter()
    for _ in range(10_000):
        with trace.span("engine.epoch", epoch=1):
            pass
    assert time.perf_counter() - t0 < 0.5       # generous absolute bound


def test_spans_do_not_change_jit_lowering(traced):
    """Spans are host-side: a jitted body lowers identically whether the
    call sites are instrumented or not, traced or not."""

    def plain(x):
        return jnp.tanh(x) * 2.0

    def instrumented(x):
        with trace.span("kernel.dispatch", kernel="t"):
            return jnp.tanh(x) * 2.0

    # the module name embeds fn.__name__; align it so the only possible
    # diff is real lowering drift
    instrumented.__name__ = "plain"

    x = jnp.ones((8, 8))
    lowered_plain = jax.jit(plain).lower(x).as_text()
    assert jax.jit(instrumented).lower(x).as_text() == lowered_plain
    ft_before = export.read_trace(trace.current_path())
    assert [s["name"] for s in ft_before.spans] == ["kernel.dispatch"]


# ---------------------------------------------------------------------------
# enabled: round-trip, nesting, export
# ---------------------------------------------------------------------------


def test_span_roundtrip_nesting_and_chrome_export(traced):
    with trace.span("runner.trial", key="k1", label="t"):
        with trace.span("engine.epoch", epoch=1):
            time.sleep(0.002)
        with trace.span("engine.epoch", epoch=2):
            pass
    trace.instant("kernel.caps_fallback", chosen="reference")

    @trace.span("study.tune", bases=1)
    def tuned():
        return 41 + 1

    assert tuned() == 42

    ft = export.read_trace(trace.current_path())
    assert ft.tag == trace.DEFAULT_TAG
    names = [s["name"] for s in ft.spans]
    # spans are written at *exit*: children precede their parent
    assert names == ["engine.epoch", "engine.epoch", "runner.trial",
                     "study.tune"]
    by_name = {s["name"]: s for s in ft.spans}
    assert by_name["runner.trial"]["depth"] == 0
    assert by_name["engine.epoch"]["depth"] == 1
    assert by_name["runner.trial"]["args"]["key"] == "k1"
    assert [i["name"] for i in ft.instants] == ["kernel.caps_fallback"]

    doc = export.to_chrome([ft])
    assert export.validate_chrome(doc) == []
    assert export.layers([ft]) == ("engine", "runner", "study")
    agg = export.breakdown([ft])
    assert agg["runner.trial"]["count"] == 1
    assert agg["engine.epoch"]["count"] == 2
    # the parent's self time excludes its children
    assert agg["runner.trial"]["self_s"] <= agg["runner.trial"]["total_s"]
    assert agg["runner.trial"]["total_s"] >= agg["engine.epoch"]["total_s"]


def test_span_records_error_and_schema_gate(traced):
    with pytest.raises(RuntimeError):
        with trace.span("sweep.execute"):
            raise RuntimeError("boom")
    ft = export.read_trace(trace.current_path())
    assert ft.spans[0]["args"]["error"] == "RuntimeError"

    # a trace stamped newer than the reader refuses to parse
    newer = traced / "trace-future-1.jsonl"
    newer.write_text(json.dumps({
        "kind": "meta", "schema": trace.TRACE_SCHEMA + 1, "pid": 1,
        "tag": "future", "t0_unix_ns": 0, "t0_perf_ns": 0}) + "\n")
    with pytest.raises(ValueError, match="newer"):
        export.read_trace(newer)


def test_report_cli_check_and_perfetto(traced, capsys):
    with trace.span("runner.trial", key="k"):
        pass
    out_json = traced / "merged.json"
    assert report.main([str(traced), "--check"]) == 0
    assert report.main([str(traced), "--perfetto", str(out_json)]) == 0
    doc = json.loads(out_json.read_text())
    assert export.validate_chrome(doc) == []
    assert any(ev.get("ph") == "X" for ev in doc["traceEvents"])
    capsys.readouterr()
    assert report.main([str(traced / "empty-subdir")]) == 1    # nothing there


def test_report_json_is_machine_readable(traced, capsys):
    """``--json`` (satellite): the tables as data — what CI smoke jobs
    parse and assert on instead of grepping human output."""
    with trace.span("runner.trial", key="k"):
        with trace.span("engine.epoch", epoch=1):
            pass
        with trace.span("engine.epoch", epoch=2):
            pass
    trace.instant("kernel.caps_fallback", chosen="reference")
    metrics.counter("serve.scored").inc(7)
    metrics.write_sidecar()
    capsys.readouterr()
    assert report.main([str(traced), "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert {"engine", "runner"} <= set(doc["layers"])
    assert doc["spans"]["engine.epoch"]["count"] == 2
    assert doc["spans"]["runner.trial"]["total_s"] >= \
        doc["spans"]["runner.trial"]["self_s"]
    assert doc["instants"] == {"kernel.caps_fallback": 1}
    assert doc["counters"]["serve.scored"] == 7
    [f] = doc["files"]
    assert f["tag"] == trace.DEFAULT_TAG and f["spans"] == 3
    assert len(doc["metrics_files"]) == 1


def test_chrome_events_stitch_skewed_per_file_clock_anchors(tmp_path):
    """Anchor stitching (satellite): two processes whose perf_counter
    epochs are wildly skewed must still land at their *wall-clock*
    relative offsets in the merged timeline — ``unix_ns`` re-anchors
    each file through its own ``(t0_unix_ns, t0_perf_ns)`` pair."""
    def write(name, tag, t0_unix, t0_perf, ts):
        p = tmp_path / name
        p.write_text("\n".join(json.dumps(r, sort_keys=True) for r in (
            {"kind": "meta", "schema": trace.TRACE_SCHEMA, "pid": 1,
             "tag": tag, "t0_unix_ns": t0_unix, "t0_perf_ns": t0_perf},
            {"kind": "span", "name": f"{tag}.work", "ts": ts,
             "dur": 1_000_000, "tid": 0, "depth": 0},
        )) + "\n")
        return p

    # A: perf epoch 0; its span starts 0.5s after its unix anchor (1.0s)
    write("trace-a-1.jsonl", "a", 1_000_000_000, 0, 500_000_000)
    # B: perf epoch 7s ahead; span 0.1s after its unix anchor (2.0s)
    write("trace-b-1.jsonl", "b", 2_000_000_000, 7_000_000_000,
          7_100_000_000)
    traces = export.collect([tmp_path])
    a, b = sorted(traces, key=lambda t: t.tag)
    assert a.unix_ns(500_000_000) == 1_500_000_000
    assert b.unix_ns(7_100_000_000) == 2_100_000_000

    evs = {ev["name"]: ev for ev in export.chrome_events(traces)
           if ev.get("ph") == "X"}
    # merged timeline is zero-based at the earliest event; the 0.6s
    # wall-clock gap survives the 7s perf-anchor skew (ts is in us)
    assert evs["a.work"]["ts"] == pytest.approx(0.0)
    assert evs["b.work"]["ts"] == pytest.approx(600_000.0)
    assert export.validate_chrome(export.to_chrome(traces)) == []


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_snapshot_is_deterministic_and_typed():
    metrics.reset()
    metrics.counter("b.hits").inc()
    metrics.counter("a.miss").inc(2)
    metrics.gauge("q.depth").set(3)
    h = metrics.histogram("lat")
    h.observe(5e-6)
    h.observe(2.0)
    snap = metrics.snapshot()
    assert snap["schema"] == metrics.METRICS_SCHEMA
    assert list(snap["counters"]) == ["a.miss", "b.hits"]    # sorted
    assert snap["counters"]["a.miss"] == 2
    assert snap["gauges"]["q.depth"] == 3.0
    hist = snap["histograms"]["lat"]
    assert hist["count"] == 2 and hist["min"] == 5e-6 and hist["max"] == 2.0
    assert len(hist["counts"]) == len(hist["edges"]) + 1
    assert snap == metrics.snapshot()                        # stable

    with pytest.raises(TypeError, match="already registered"):
        metrics.gauge("a.miss")
    with pytest.raises(ValueError, match="edges"):
        metrics.histogram("lat", edges=(1.0, 2.0))
    metrics.reset()


def test_metrics_sidecar_piggybacks_on_tracing(tmp_path, monkeypatch):
    monkeypatch.delenv(trace.ENV_TRACE, raising=False)
    trace.refresh()
    metrics.reset()
    metrics.counter("x").inc()
    assert metrics.write_sidecar() is None      # disabled: no default path

    monkeypatch.setenv(trace.ENV_TRACE, "1")
    monkeypatch.setenv(trace.ENV_TRACE_DIR, str(tmp_path))
    trace.refresh()
    p = metrics.write_sidecar()
    assert p is not None and p.parent == tmp_path
    assert json.loads(p.read_text())["counters"]["x"] == 1
    monkeypatch.delenv(trace.ENV_TRACE, raising=False)
    trace.refresh()
    metrics.reset()


# ---------------------------------------------------------------------------
# store event schema (satellite)
# ---------------------------------------------------------------------------


def test_store_events_are_schema_stamped_and_validated(tmp_path):
    st = store.StudyStore(tmp_path / "out.json",
                          jsonl_path=tmp_path / "runs.jsonl")
    st.record_event("sweep_shard", worker=0, returncode=0)
    st.write()
    events = store.load_events(tmp_path / "runs.jsonl")
    assert [e["event"] for e in events] == ["sweep_shard"]
    assert events[0]["schema"] == store.EVENT_SCHEMA
    assert store.load_events(tmp_path / "runs.jsonl",
                             kinds=("sweep_merge",)) == []

    # legacy (pre-stamp) lines load; newer-than-reader lines refuse
    with open(tmp_path / "runs.jsonl", "a") as f:
        f.write(json.dumps({"event": "legacy_kind"}) + "\n")
    assert [e["event"] for e in store.load_events(tmp_path / "runs.jsonl")] \
        == ["sweep_shard", "legacy_kind"]
    with open(tmp_path / "runs.jsonl", "a") as f:
        f.write(json.dumps({"event": "future",
                            "schema": store.EVENT_SCHEMA + 1}) + "\n")
    with pytest.raises(ValueError, match="newer"):
        store.load_events(tmp_path / "runs.jsonl")


def test_kernel_bench_store_records_events(tmp_path):
    st = store.KernelBenchStore(tmp_path / "k.json",
                                jsonl_path=tmp_path / "k.jsonl")
    st.record_event("timing_stats", label="x", median=1e-3, std=1e-5)
    st.record_entry("x", {"wall_s": 1e-3})
    st.write()
    # dispersion lands in the sidecar, never the deterministic snapshot
    assert "timing_stats" not in (tmp_path / "k.json").read_text()
    [ev] = store.load_events(tmp_path / "k.jsonl")
    assert ev["event"] == "timing_stats" and ev["std"] == 1e-5


# ---------------------------------------------------------------------------
# timing dispersion (satellite)
# ---------------------------------------------------------------------------


def test_time_stats_shape_and_median_consistency():
    stats = time_stats(lambda: sum(range(50)), warmup=1, iters=5)
    assert set(stats) == {"median", "min", "mean", "std", "iters"}
    assert stats["iters"] == 5
    assert stats["min"] <= stats["median"] <= stats["min"] + stats["std"] * 5 \
        or stats["median"] >= stats["min"]
    assert stats["min"] <= stats["mean"]
    assert median_time(lambda: 1, warmup=0, iters=3) >= 0.0


# ---------------------------------------------------------------------------
# traced 2-worker sweep stitches into one timeline
# ---------------------------------------------------------------------------


def test_traced_two_worker_sweep_produces_stitchable_timeline(traced,
                                                              tmp_path):
    trials = list(spec.grid(
        [spec.DatasetSpec(d, max_n=96) for d in ("covtype", "w8a")],
        ["lr"], [sgd.SyncSGD()], steps=(1e-2, 1e-1), epochs=2))
    ex = LocalProcessExecutor(workers=2, work_dir=tmp_path / "work")
    st = store.StudyStore(tmp_path / "out.json",
                          jsonl_path=tmp_path / "runs.jsonl")
    Runner(cache_dir=tmp_path / "cache", store=st, executor=ex).run(trials)
    st.write()

    traces = export.collect([traced])
    tags = {t.tag for t in traces}
    assert trace.DEFAULT_TAG in tags                   # the driver
    assert {"shard0a0", "shard1a0"} <= tags            # one file per worker
    # the merged view spans driver + worker layers
    layer_set = set(export.layers(traces))
    assert {"sweep", "runner", "engine"} <= layer_set
    doc = export.to_chrome(traces)
    assert export.validate_chrome(doc) == []
    assert report.main([str(traced), "--check"]) == 0

    # provenance events carry each attempt's trace file path
    shard_events = store.load_events(tmp_path / "runs.jsonl",
                                     kinds=("sweep_shard",))
    assert {e["worker"] for e in shard_events} == {0, 1}
    for e in shard_events:
        assert e["trace_file"] and "shard" in e["trace_file"]
