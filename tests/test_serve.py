"""Serve-layer tests: the LM slot engine and the GLM scoring service.

The first test suite the serve layer has ever had.  Covers the
``ServeEngine`` regression fixes (empty-prompt admission, dead ``done``
accumulator), the ``GLMScoreEngine`` admission/batching/scoring
semantics, property-based admission invariants for *both* engines
(hypothesis: arbitrary admit/tick interleavings lose nothing, duplicate
nothing, respect capacity and FIFO, and terminate), and the hot-swap
chaos test: every response under concurrent ``swap_model`` fire is
consistent with exactly one published snapshot.
"""
import functools
import inspect
import random
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # Fallback property-test driver: the admission invariants below are
    # tier-1 and must run even without the dev extra (CI does not install
    # hypothesis — test_properties.py skips there).  This implements
    # exactly the strategy subset used in this file, drawing from a
    # seeded ``random.Random`` per example, so the tests stay
    # deterministic and still explore many interleavings.  With
    # hypothesis installed the real engine (shrinking, coverage-guided
    # generation) takes over transparently.
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

    class st:  # noqa: N801 — mirrors ``hypothesis.strategies``
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: rng.randint(lo, hi))

        @staticmethod
        def sampled_from(items):
            return _Strategy(lambda rng: rng.choice(list(items)))

        @staticmethod
        def tuples(*ss):
            return _Strategy(lambda rng: tuple(s._draw(rng) for s in ss))

        @staticmethod
        def lists(elt, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elt._draw(rng)
                for _ in range(rng.randint(min_size, max_size))])

        @staticmethod
        def data():
            return _Strategy(None)      # resolved by ``given`` below

    class _Data:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy._draw(self._rng)

    def settings(max_examples=10, deadline=None):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**gkw):
        ((name, _),) = gkw.items()      # only the data=st.data() form

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                for seed in range(getattr(fn, "_max_examples", 10)):
                    fn(*args, **{name: _Data(random.Random(seed))},
                       **kwargs)
            # hide the drawn param so pytest doesn't look for a fixture
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for p in sig.parameters.values() if p.name != name])
            del wrapper.__wrapped__
            return wrapper
        return deco

from repro.core.glm import LINKS
from repro.serve.engine import Request, ServeEngine
from repro.serve.glm import GLMScoreEngine, ModelSnapshot, ScoreRequest


# ---------------------------------------------------------------------------
# shared fixtures
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def lm_setup():
    """One tiny transformer per module — ServeEngine tests share the jit."""
    from repro import configs
    from repro.nn import transformer

    cfg = configs.reduced(configs.get("minitron-4b"))
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _score_engine(task="lr", d=24, k=3, **kw):
    rng = np.random.default_rng(3)
    w = rng.normal(0, 0.4, d).astype(np.float32)
    kw.setdefault("max_batch", 4)
    kw.setdefault("queue_depth", 6)
    return GLMScoreEngine(task, w, ell_width=k, **kw), w


def _req(rid, d=24, k=3, seed=None):
    rng = np.random.default_rng(rid if seed is None else seed)
    nn = int(rng.integers(1, k + 1))
    idx = rng.choice(d, nn, replace=False)
    return ScoreRequest(rid, rng.normal(0, 1, nn), idx)


def _oracle(task, w, req):
    m = float(np.sum(np.asarray(req.values, np.float32)
                     * w[np.asarray(req.indices, np.int64)]))
    return float(LINKS[task](jnp.float32(m)))


# ---------------------------------------------------------------------------
# ServeEngine regressions (the seed's untested slot loop)
# ---------------------------------------------------------------------------


def test_serve_engine_empty_prompt_admits(lm_setup):
    """Empty prompts used to raise UnboundLocalError in try_admit
    (``logits`` was only bound inside the prefill loop)."""
    cfg, params = lm_setup
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    req = Request(0, np.asarray([], np.int32), max_new=3)
    assert eng.try_admit(req)           # no crash, slot taken
    assert eng.live[0] is req
    assert req.out == []                # no prompt-conditioned token yet
    done = eng.run([req], max_ticks=20)
    assert done == [req] and req.done
    assert 1 <= len(req.out) <= req.max_new + 1
    assert all(0 <= t < cfg.vocab for t in req.out)


def test_serve_engine_run_mixed_empty_and_real_prompts(lm_setup):
    cfg, params = lm_setup
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    reqs = [Request(0, np.asarray([], np.int32), max_new=2),
            Request(1, np.asarray([1, 2], np.int32), max_new=2),
            Request(2, np.asarray([], np.int32), max_new=2)]
    done = eng.run(reqs, max_ticks=50)
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(r.done and len(r.out) >= 1 for r in reqs)


def test_serve_engine_run_returns_each_request_once(lm_setup):
    """run() must report every finished request exactly once (the old
    dead ``done`` accumulator duplicated this bookkeeping)."""
    cfg, params = lm_setup
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    reqs = [Request(i, np.asarray([1 + i], np.int32), max_new=2)
            for i in range(3)]
    done = eng.run(reqs, max_ticks=50)
    assert [r.rid for r in done] == [0, 1, 2]
    assert len({id(r) for r in done}) == 3


# ---------------------------------------------------------------------------
# ServeEngine admission properties (hypothesis)
# ---------------------------------------------------------------------------


@given(data=st.data())
@settings(max_examples=5, deadline=None)
def test_serve_engine_admission_properties(lm_setup, data):
    """Arbitrary admit/tick interleavings: capacity respected, FIFO
    admission, nothing lost or duplicated, every admitted request
    terminates within its max_new bound."""
    cfg, params = lm_setup
    slots = data.draw(st.integers(1, 2), label="slots")
    specs = data.draw(st.lists(
        st.tuples(st.integers(0, 2), st.integers(1, 3)),
        min_size=1, max_size=4), label="(prompt_len, max_new)")
    ops = data.draw(st.lists(st.sampled_from(["admit", "tick"]),
                             max_size=8), label="ops")
    eng = ServeEngine(cfg, params, slots=slots, max_len=32)
    pending = [Request(i, np.arange(1, 1 + p, dtype=np.int32), max_new=m)
               for i, (p, m) in enumerate(specs)]
    admitted = []
    for op in ops + ["admit", "tick"] * (4 * len(specs)):
        live = [r for r in eng.live if r is not None]
        assert len(live) <= slots
        if op == "admit" and pending:
            if eng.try_admit(pending[0]):
                admitted.append(pending.pop(0))
            else:
                assert all(r is not None for r in eng.live)  # full => reject
        else:
            eng.tick()
        if not pending and all(r is None for r in eng.live):
            break
    # FIFO: requests were admitted in submission order
    assert [r.rid for r in admitted] == sorted(r.rid for r in admitted)
    # nothing lost, nothing duplicated, everything terminated in bound
    assert len(admitted) == len(specs)
    for r in admitted:
        assert r.done
        assert 1 <= len(r.out) <= r.max_new + 1


# ---------------------------------------------------------------------------
# GLMScoreEngine: admission, padded batching, scoring
# ---------------------------------------------------------------------------


def test_score_engine_scores_match_links():
    for task in ("lr", "svm"):
        eng, w = _score_engine(task)
        reqs = [_req(i) for i in range(3)]
        for r in reqs:
            assert eng.try_admit(r)
        out = eng.flush()               # 3 real rows in an 8-row padded batch
        assert [r.rid for r in out] == [0, 1, 2]
        for resp, req in zip(out, reqs):
            assert resp.score == pytest.approx(_oracle(task, w, req),
                                               abs=1e-4)
            assert resp.model_version == 0
            assert resp.latency_s >= 0.0


def test_score_engine_bounded_fifo_rejects_when_full():
    eng, _ = _score_engine(queue_depth=2)
    assert eng.try_admit(_req(0))
    assert eng.try_admit(_req(1))
    assert not eng.try_admit(_req(2))   # bounded: reject, don't buffer
    assert len(eng) == 2
    eng.flush()
    assert eng.try_admit(_req(2))       # space freed by the flush


def test_score_engine_flush_is_fifo_across_batches():
    eng, _ = _score_engine(max_batch=2, queue_depth=8)
    for i in range(5):
        assert eng.try_admit(_req(i))
    rids = [r.rid for r in eng.drain()]
    assert rids == [0, 1, 2, 3, 4]


def test_score_engine_rejects_malformed_rows():
    eng, _ = _score_engine(k=3)
    with pytest.raises(ValueError, match="exceed"):
        eng.try_admit(ScoreRequest(0, np.ones(4), np.arange(4)))
    with pytest.raises(ValueError, match="mismatch"):
        eng.try_admit(ScoreRequest(1, np.ones(2), np.arange(3)))
    with pytest.raises(ValueError, match="unknown task"):
        GLMScoreEngine("poisson", np.ones(4), ell_width=2)


def test_score_engine_flush_deadline_with_injected_clock():
    now = [0.0]
    eng, _ = _score_engine(max_batch=4, queue_depth=8,
                           flush_deadline_s=0.5, clock=lambda: now[0])
    assert eng.try_admit(_req(0))
    assert eng.maybe_flush() == []      # 1 of 4 rows, deadline not reached
    now[0] = 0.6
    out = eng.maybe_flush()             # oldest row overdue -> flush
    assert [r.rid for r in out] == [0]
    assert out[0].latency_s == pytest.approx(0.6)
    for i in range(1, 5):
        assert eng.try_admit(_req(i))
    assert len(eng.maybe_flush()) == 4  # full batch flushes regardless


def test_score_engine_swap_model_atomic_versioning():
    eng, w = _score_engine("svm", d=24)
    assert eng.model.version == 0
    snap = eng.swap_model(np.zeros(24, np.float32))
    assert isinstance(snap, ModelSnapshot) and snap.version == 1
    assert eng.model is snap
    assert eng.try_admit(_req(7))
    (resp,) = eng.flush()
    assert resp.model_version == 1 and resp.score == 0.0
    with pytest.raises(ValueError, match="shape mismatch"):
        eng.swap_model(np.zeros(23, np.float32))


@given(data=st.data())
@settings(max_examples=20, deadline=None)
def test_score_engine_admission_properties(data):
    """Arbitrary admit/flush/maybe_flush/swap interleavings: the bounded
    queue never overfills, responses are FIFO with no loss or dup, and
    a final drain always terminates the backlog."""
    eng, w = _score_engine("lr", max_batch=3, queue_depth=5)
    n = data.draw(st.integers(1, 12), label="n_requests")
    ops = data.draw(st.lists(
        st.sampled_from(["admit", "flush", "maybe", "swap"]),
        max_size=20), label="ops")
    pending = [_req(i) for i in range(n)]
    admitted, responses, version = [], [], 0
    for op in ops:
        assert len(eng) <= eng.queue_depth
        if op == "admit" and pending:
            full = len(eng) >= eng.queue_depth
            ok = eng.try_admit(pending[0])
            assert ok == (not full)     # rejects exactly when full
            if ok:
                admitted.append(pending.pop(0))
        elif op == "flush":
            responses.extend(eng.flush())
        elif op == "maybe":
            responses.extend(eng.maybe_flush())
        else:
            version += 1
            eng.swap_model(np.roll(w, version))
    responses.extend(eng.drain())
    assert len(eng) == 0
    # FIFO, no loss, no duplication — and every response's stamped
    # version is one that was actually published
    assert [r.rid for r in responses] == [r.rid for r in admitted]
    assert all(0 <= r.model_version <= version for r in responses)


# ---------------------------------------------------------------------------
# Hot-swap chaos: concurrent swap_model vs a steady scoring stream
# ---------------------------------------------------------------------------


def test_score_engine_hot_swap_chaos():
    """Score a steady request stream while swap_model fires from another
    thread: every response must match the oracle under exactly the ONE
    snapshot version it is stamped with (never a torn mix), and the
    stream keeps flowing (throughput never drops to zero)."""
    d, k, n_swaps = 32, 4, 25
    rng = np.random.default_rng(11)
    models = {v: rng.normal(0, 0.5, d).astype(np.float32)
              for v in range(n_swaps + 1)}
    eng = GLMScoreEngine("svm", models[0], ell_width=k, max_batch=8,
                         queue_depth=32)

    stop = threading.Event()

    def swapper():
        for v in range(1, n_swaps + 1):
            eng.swap_model(models[v])
            time.sleep(0.002)
        stop.set()

    th = threading.Thread(target=swapper)
    responses, reqs, rid = [], {}, 0
    th.start()
    try:
        # keep admitting + flushing while the swapper is alive, then once
        # more after it finished so the final version is observed too
        while not stop.is_set() or rid == 0:
            for _ in range(8):
                r = _req(rid, d=d, k=k)
                reqs[rid] = r
                assert eng.try_admit(r)
                rid += 1
            batch = eng.flush()
            assert batch, "throughput dropped to zero mid-stream"
            responses.extend(batch)
    finally:
        th.join()
    # one more round after the swapper finished: the final published
    # model must actually serve
    for _ in range(8):
        r = _req(rid, d=d, k=k)
        reqs[rid] = r
        assert eng.try_admit(r)
        rid += 1
    responses.extend(eng.drain())
    assert [r.rid for r in responses] == list(range(rid))  # nothing lost

    mismatched = []
    for resp in responses:
        w_v = models[resp.model_version]        # the ONE stamped snapshot
        want = _oracle("svm", w_v, reqs[resp.rid])
        if resp.score != pytest.approx(want, abs=1e-4):
            mismatched.append((resp.rid, resp.model_version))
    assert not mismatched, f"responses inconsistent w/ snapshot: {mismatched}"
    versions = {r.model_version for r in responses}
    assert len(versions) >= 2, "swaps never interleaved with scoring"
    assert max(versions) == n_swaps     # the last published model served
