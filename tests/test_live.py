"""Live subsystem tests: streams, the replica-merge learner, publishing,
and the train-while-serve chaos run.

The contract under test, from docs/LIVE.md:

* streams are deterministic and replayable — chunk ``i`` is a pure
  function of ``(seed, i)``; the libsvm stream re-reads the same file
  bytes into the same batches and wraps at EOF;
* the learner converges on the stream's planted model, merges only the
  alive replicas, freezes dead ones, re-seeds them from the merged
  anchor on revival, and never stalls (all-dead merges are skipped, the
  stream keeps flowing);
* the compressed (int8 + error feedback) merge path tracks the exact
  path within quantization tolerance;
* the publisher stamps every snapshot with the learner step, versions
  strictly increase, and the published model never lags training by
  more than ``every_merges * merge_every`` steps;
* under concurrent serving + kill/revive chaos, every response is
  consistent with exactly ONE published snapshot (the torn-read check),
  staleness stays inside the bound, and scoring throughput never drops
  to zero.
"""
import threading

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import sparse
from repro.core.glm import LINKS
from repro.data.ingest.libsvm import LibsvmFormatError
from repro.live import (LibsvmStream, LiveConfig, LiveLearner,
                        SnapshotPublisher, SyntheticStream)
from repro.serve.glm import GLMScoreEngine, ScoreRequest

D, NB = 32, 64


def _stream(seed=3, **kw):
    kw.setdefault("n_batch", NB)
    kw.setdefault("d", D)
    return SyntheticStream(seed=seed, **kw)


def _cfg(**kw):
    kw.setdefault("task", "lr")
    kw.setdefault("replicas", 4)
    kw.setdefault("step_size", 0.2)
    kw.setdefault("merge_every", 2)
    return LiveConfig(**kw)


def _libsvm_file(tmp_path, n_rows=25, d=10, zero_based=False):
    rng = np.random.default_rng(7)
    lo = 0 if zero_based else 1
    lines = []
    for _ in range(n_rows):
        label = int(rng.random() < 0.5)
        nnz = int(rng.integers(1, 5))
        idx = np.sort(rng.choice(np.arange(lo, d + lo), nnz, replace=False))
        feats = " ".join(f"{j}:{rng.normal():.4f}" for j in idx)
        lines.append(f"{label} {feats}")
    p = tmp_path / "stream.svm"
    p.write_text("\n".join(lines) + "\n")
    return p


# ---------------------------------------------------------------------------
# streams
# ---------------------------------------------------------------------------


def test_synthetic_stream_is_pure_function_of_seed_and_seq():
    a, b = _stream(seed=9), _stream(seed=9)
    for i in (0, 3, 17):
        ba, bb = a.batch(i), b.batch(i)
        assert ba.seq == i
        np.testing.assert_array_equal(ba.values, bb.values)
        np.testing.assert_array_equal(ba.indices, bb.indices)
        np.testing.assert_array_equal(ba.y, bb.y)
    # random access == iteration order
    it = iter(a)
    np.testing.assert_array_equal(next(it).values, a.batch(0).values)
    np.testing.assert_array_equal(next(it).values, a.batch(1).values)
    # different seeds diverge
    assert not np.array_equal(a.batch(0).values, _stream(seed=10).batch(0).values)


def test_synthetic_stream_shapes_and_holdout():
    s = _stream()
    b = s.batch(0)
    assert b.values.shape == (NB, s.ell_width)
    assert b.indices.shape == (NB, s.ell_width)
    assert b.indices.dtype == np.int32
    assert set(np.unique(b.y)) <= {-1.0, 1.0}
    ell, y = s.holdout(128)
    assert ell.values.shape == (128, s.ell_width) and len(y) == 128
    assert s.n_batch == NB              # holdout must not clobber the config
    ell2, y2 = s.holdout(128)
    np.testing.assert_array_equal(np.asarray(ell.values),
                                  np.asarray(ell2.values))
    # dense profile carries the dense view
    ds = _stream(dense=True, n_batch=8, d=6)
    db = ds.batch(0)
    assert db.X.shape == (8, 6) and ds.ell_width == 6


def test_libsvm_stream_replays_and_wraps(tmp_path):
    p = _libsvm_file(tmp_path)
    a = LibsvmStream(p, n_batch=8, d=10, ell_width=4)
    first = [a.batch() for _ in range(4)]
    assert [b.seq for b in first] == [0, 1, 2, 3]
    assert set(np.unique(first[0].y)) <= {-1.0, 1.0}   # {0,1} auto-mapped
    # replay from a fresh reader: identical bytes -> identical batches
    b0 = LibsvmStream(p, n_batch=8, d=10, ell_width=4).batch()
    np.testing.assert_array_equal(first[0].values, b0.values)
    np.testing.assert_array_equal(first[0].y, b0.y)
    # 25 rows / chunks of 8: batch 3 wrapped to the file start
    np.testing.assert_array_equal(first[3].values[1], first[0].values[0])
    # loop=False: 3 full chunks, the 1-row tail is dropped
    assert len(list(LibsvmStream(p, n_batch=8, d=10, ell_width=4,
                                 loop=False))) == 3


def test_libsvm_stream_rejects_bad_indices(tmp_path):
    p0 = _libsvm_file(tmp_path, zero_based=True)
    with pytest.raises(LibsvmFormatError, match="1-based"):
        for _ in LibsvmStream(p0, n_batch=8, d=10, ell_width=4):
            pass
    # same file read correctly as 0-based
    b = LibsvmStream(p0, n_batch=8, d=10, ell_width=4,
                     zero_based=True).batch()
    assert b.indices.max() < 10
    # out-of-range feature vs the pinned d
    with pytest.raises(LibsvmFormatError, match="out of range"):
        LibsvmStream(p0, n_batch=8, d=5, ell_width=4,
                     zero_based=True).batch()


# ---------------------------------------------------------------------------
# learner
# ---------------------------------------------------------------------------


def test_live_learner_converges_and_merges():
    s = _stream()
    lrn = LiveLearner(_cfg(), s)
    ell, y = s.holdout(256)
    l0 = lrn.loss(ell, y)
    lrn.run(40)
    assert lrn.steps == 40 and lrn.merges == 20
    assert lrn.loss(ell, y) < 0.6 * l0
    # after a merge all alive replicas hold the merged model
    W = np.asarray(lrn.W)
    anchor = np.asarray(lrn.anchor)
    for r in range(4):
        np.testing.assert_allclose(W[r], anchor, rtol=1e-6)


def test_live_learner_validates_local_batch():
    with pytest.raises(ValueError, match="local_batch must divide"):
        # per-replica partition is 16; 5 does not divide it
        LiveLearner(_cfg(local_batch=5), _stream())


def test_live_learner_compressed_merge_tracks_exact():
    s = _stream(seed=4)
    ell, y = s.holdout(256)
    exact = LiveLearner(_cfg(), s).run(30)
    comp = LiveLearner(_cfg(compress=True), s).run(30)
    le, lc = exact.loss(ell, y), comp.loss(ell, y)
    assert lc == pytest.approx(le, rel=0.05)   # int8+EF: same trajectory
    # the error-feedback buffer is live (carries nonzero residual)
    assert float(jnp.abs(comp._ef).sum()) > 0.0


def test_live_learner_kernel_dispatch_path():
    s = _stream(seed=6)
    ell, y = s.holdout(256)
    lrn = LiveLearner(_cfg(local_batch=8, replicas=2,
                           kernel_backend="pallas-interpret"), s)
    l0 = lrn.loss(ell, y)
    lrn.run(12)
    assert lrn.loss(ell, y) < l0
    # and the pure-XLA path with the same batching agrees on the merged
    # model (same data order, same math)
    ref = LiveLearner(_cfg(local_batch=8, replicas=2), _stream(seed=6))
    ref.run(12)
    np.testing.assert_allclose(np.asarray(lrn.anchor), np.asarray(ref.anchor),
                               atol=1e-4)


def test_live_learner_dead_replica_frozen_and_dropped():
    lrn = LiveLearner(_cfg(), _stream())
    lrn.run(6)
    lrn.kill(2)
    assert lrn.alive().tolist() == [True, True, False, True]
    w_dead = np.asarray(lrn.W[2]).copy()
    lrn.run(6)
    np.testing.assert_array_equal(np.asarray(lrn.W[2]), w_dead)  # frozen
    # the merge excluded the dead row: alive rows share the anchor, the
    # dead one does not
    anchor = np.asarray(lrn.anchor)
    assert not np.allclose(w_dead, anchor)
    np.testing.assert_allclose(np.asarray(lrn.W[0]), anchor, rtol=1e-6)
    # revival re-seeds from the merged model and resumes training
    lrn.revive(2)
    np.testing.assert_array_equal(np.asarray(lrn.W[2]), anchor)
    lrn.run(1)
    assert not np.allclose(np.asarray(lrn.W[2]), anchor)  # training again


def test_live_learner_all_dead_skips_merge_but_streams_on():
    lrn = LiveLearner(_cfg(), _stream())
    for r in range(4):
        lrn.kill(r)
    lrn.run(4)
    assert lrn.steps == 4               # the stream kept flowing
    assert lrn.merges == 0 and lrn.merges_skipped == 2
    np.testing.assert_array_equal(np.asarray(lrn.W),
                                  np.zeros((4, D), np.float32))
    lrn.revive(0)
    lrn.run(2)
    assert lrn.merges == 1              # consensus resumes with one replica


# ---------------------------------------------------------------------------
# publisher
# ---------------------------------------------------------------------------


def test_publisher_stamps_steps_and_bounds_staleness():
    s = _stream()
    eng = GLMScoreEngine("lr", np.zeros(D, np.float32),
                         ell_width=s.ell_width, max_batch=8)
    lrn = LiveLearner(_cfg(), s)
    pub = SnapshotPublisher(eng, every_merges=2).attach(lrn)
    assert eng.model.version == 0 and eng.model.step is None
    lrn.run(20)                          # 10 merges -> 5 publishes
    assert pub.publishes == 5
    assert eng.model.version == 5
    assert eng.model.step == 20
    versions = [h["version"] for h in pub.history]
    steps = [h["step"] for h in pub.history]
    assert versions == [1, 2, 3, 4, 5]          # strictly increasing
    assert steps == [4, 8, 12, 16, 20]          # stamped learner steps
    bound = pub.bound_steps(lrn.config.merge_every)   # 2 * 2 = 4
    # walk every step: the published model never lags more than `bound`
    for _ in range(17):
        lrn.step()
        assert pub.staleness(lrn) <= bound
    # the published snapshot really is the merged model at that step
    np.testing.assert_allclose(np.asarray(eng.model.w),
                               np.asarray(lrn.anchor), rtol=1e-6)


def test_publisher_validates_period():
    eng = GLMScoreEngine("lr", np.zeros(4, np.float32), ell_width=2)
    with pytest.raises(ValueError, match="every_merges"):
        SnapshotPublisher(eng, every_merges=0)


# ---------------------------------------------------------------------------
# chaos: train while serving, kill/revive mid-stream
# ---------------------------------------------------------------------------


def _score_oracle(task, w, values, indices):
    m = float(np.sum(values * w[np.asarray(indices, np.int64)]))
    return float(LINKS[task](jnp.float32(m)))


def test_live_chaos_train_while_serving():
    """The ISSUE acceptance run: a learner trains + publishes while a
    scoring thread serves, replicas die and revive mid-stream.  Checks:
    (1) fault-run convergence lands within tolerance of the no-fault
    run; (2) every response is consistent with exactly one published
    snapshot (score matches that version's weights — no torn reads) and
    versions are non-decreasing in admission order; (3) staleness never
    exceeds the publisher bound; (4) scoring throughput is never zero.
    """
    s = _stream(seed=12)
    ell, y = s.holdout(256)
    n_steps = 48

    # -- baseline: same stream, no faults, no serving
    base = LiveLearner(_cfg(), _stream(seed=12)).run(n_steps)
    base_loss = base.loss(ell, y)

    # -- chaos run
    lrn = LiveLearner(_cfg(), s)
    eng = GLMScoreEngine("lr", np.zeros(D, np.float32),
                         ell_width=s.ell_width, max_batch=8, queue_depth=64)
    pub = SnapshotPublisher(eng, every_merges=1).attach(lrn)
    bound = pub.bound_steps(lrn.config.merge_every)
    published = {0: np.zeros(D, np.float32)}   # version -> weights
    lrn.add_merge_hook(lambda l: published.setdefault(
        eng.model.version, np.asarray(eng.model.w).copy()))

    responses, requests = [], {}
    flushes, empty_flushes = [], 0
    stop = threading.Event()
    rng = np.random.default_rng(0)

    def server():
        rid = 0
        while not stop.is_set():
            for _ in range(4):
                nn = int(rng.integers(1, s.ell_width + 1))
                idx = rng.choice(D, nn, replace=False)
                req = ScoreRequest(rid, rng.normal(0, 1, nn), idx)
                if eng.try_admit(req):
                    requests[rid] = req
                    rid += 1
            out = eng.flush()
            flushes.append(len(out))
            responses.extend(out)
        responses.extend(eng.drain())

    th = threading.Thread(target=server)
    th.start()
    try:
        for i in range(n_steps):
            lrn.step()
            lag = pub.staleness(lrn)
            assert lag is None or lag <= bound
            if i == 12:
                lrn.kill(1)
                lrn.kill(3)
            if i == 28:
                lrn.revive(1)
                lrn.revive(3)
    finally:
        stop.set()
        th.join()

    # (1) convergence within tolerance of the fault-free run
    chaos_loss = lrn.loss(ell, y)
    assert chaos_loss < 1.35 * base_loss, (chaos_loss, base_loss)

    # (2) every response consistent with exactly ONE published snapshot
    assert responses, "server thread never scored anything"
    for resp in responses:
        assert resp.model_version in published
        req = requests[resp.rid]
        want = _score_oracle("lr", published[resp.model_version],
                             np.asarray(req.values, np.float32),
                             req.indices)
        assert resp.score == pytest.approx(want, abs=1e-4), resp
    seen = [r.model_version for r in responses]
    assert seen == sorted(seen)          # single consumer: non-decreasing
    assert max(seen) >= 1                # swaps really interleaved

    # (3) the final published model is the final merged model
    np.testing.assert_allclose(np.asarray(eng.model.w),
                               np.asarray(lrn.anchor), rtol=1e-6)

    # (4) throughput never zero: every server round either admitted
    # fresh rows or the queue was full — both make the flush non-empty
    assert flushes and all(flushes), "scoring throughput dropped to zero"
    assert lrn.merges >= n_steps // lrn.config.merge_every - 1
