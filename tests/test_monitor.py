"""Runtime health monitor: digest, SLOs, drift watch, windows, CLI.

The acceptance contract of the subsystem (ISSUE 10):
* the quantile digest is bounded, deterministic, mergeable, and clamps
  its interpolated read-out to the observed range;
* SLO predicates evaluate per closed window — empty windows are no-op
  rolls, never vacuous breaches — and each breach lands as an
  ``slo.breach.<name>`` counter plus an ``slo.breach`` trace instant;
* the EWMA drift watch flags rising / non-finite loss curves and never
  flags a clean descending one (the committed BENCH_live curves);
* staleness is measured against the publisher's bound captured at
  attach time, so a stalled publisher breaches instead of relaxing it;
* ``python -m repro.obs.monitor --check`` exits 0 on a clean monitored
  run and nonzero per breach; ``REPRO_METRICS=1`` persists sidecars
  without span tracing.
"""
import json
import math

import numpy as np
import pytest

from repro.live import LiveConfig, LiveLearner, SnapshotPublisher, \
    SyntheticStream
from repro.obs import export, metrics, trace
from repro.obs.digest import LATENCY_EDGES, QuantileDigest
from repro.obs.monitor import (DEFAULT_LIVE_SLOS, DEFAULT_SERVE_SLOS,
                               EWMADrift, HealthMonitor, SLOSpec)
from repro.obs import monitor as monitor_mod
from repro.serve.glm import GLMScoreEngine, ScoreRequest

TASK = "lr"


@pytest.fixture
def traced(tmp_path, monkeypatch):
    """Tracing (and thus metrics persistence) into a temp dir."""
    monkeypatch.setenv(trace.ENV_TRACE, "1")
    monkeypatch.setenv(trace.ENV_TRACE_DIR, str(tmp_path))
    monkeypatch.delenv(trace.ENV_TRACE_TAG, raising=False)
    trace.refresh()
    metrics.reset()
    metrics._last_flush = 0.0
    yield tmp_path
    monkeypatch.delenv(trace.ENV_TRACE, raising=False)
    trace.refresh()
    metrics.reset()


@pytest.fixture
def metrics_only(tmp_path, monkeypatch):
    """REPRO_METRICS=1 with tracing OFF (satellite: decoupled sidecar)."""
    monkeypatch.delenv(trace.ENV_TRACE, raising=False)
    monkeypatch.setenv(metrics.ENV_METRICS, "1")
    monkeypatch.setenv(trace.ENV_TRACE_DIR, str(tmp_path))
    monkeypatch.delenv(trace.ENV_TRACE_TAG, raising=False)
    trace.refresh()
    metrics.reset()
    metrics._last_flush = 0.0
    yield tmp_path
    monkeypatch.delenv(metrics.ENV_METRICS, raising=False)
    trace.refresh()
    metrics.reset()


# ---------------------------------------------------------------------------
# quantile digest
# ---------------------------------------------------------------------------


def test_digest_quantiles_interpolate_and_clamp_to_observed_range():
    d = QuantileDigest()
    assert d.quantile(0.5) is None and d.mean is None       # empty
    for v in (0.001, 0.002, 0.003, 0.004, 0.100):
        d.observe(v)
    assert d.quantile(0.0) == pytest.approx(0.001)          # exact min
    assert d.quantile(1.0) == pytest.approx(0.100)          # exact max
    p50 = d.quantile(0.5)
    assert 0.001 <= p50 <= 0.0056                           # within bucket
    assert d.quantile(0.25) <= p50 <= d.quantile(0.99)      # monotone in q
    assert d.mean == pytest.approx(0.022)
    # clamp: every estimate stays inside [min, max]
    for q in (0.0, 0.1, 0.5, 0.9, 0.99, 1.0):
        assert 0.001 <= d.quantile(q) <= 0.100
    with pytest.raises(ValueError, match="in \\[0, 1\\]"):
        d.quantile(1.5)


def test_digest_is_deterministic_and_bounded():
    a, b = QuantileDigest(), QuantileDigest()
    vals = [10.0 ** (i % 7 - 5) for i in range(1000)]
    for v in vals:
        a.observe(v)
    for v in reversed(vals):                # order must not matter
        b.observe(v)
    sa, sb = a.snapshot(), b.snapshot()
    assert sb["sum"] == pytest.approx(sa.pop("sum"))        # fp assoc. only
    sb.pop("sum")
    assert sa == sb                         # counts/quantile state identical
    assert a.quantile(0.99) == b.quantile(0.99)
    assert len(a.counts) == len(LATENCY_EDGES) + 1          # fixed memory


def test_digest_merge_and_snapshot_roundtrip():
    a, b = QuantileDigest(), QuantileDigest()
    for v in (0.001, 0.002):
        a.observe(v)
    for v in (0.5, 2.0):
        b.observe(v)
    a.merge(b)
    assert a.count == 4 and a.min == 0.001 and a.max == 2.0
    back = QuantileDigest.from_snapshot(a.snapshot())
    assert back.snapshot() == a.snapshot()
    assert back.quantile(0.99) == a.quantile(0.99)
    with pytest.raises(ValueError, match="different edges"):
        a.merge(QuantileDigest((1.0, 2.0)))
    with pytest.raises(ValueError, match="sorted"):
        QuantileDigest((2.0, 1.0))
    with pytest.raises(ValueError, match="buckets"):
        QuantileDigest.from_snapshot({"edges": [1.0], "counts": [1, 2, 3],
                                      "count": 6, "sum": 1.0,
                                      "min": 0.1, "max": 1.0})


# ---------------------------------------------------------------------------
# SLO specs
# ---------------------------------------------------------------------------


def test_slospec_predicates_and_validation():
    ceil = SLOSpec("lat", "p99_s", "<=", 0.5)
    floor = SLOSpec("tput", "rps", ">=", 1.0)
    assert ceil.holds(0.5) and not ceil.holds(0.50001)
    assert floor.holds(1.0) and not floor.holds(0.9)
    assert ceil.to_dict()["op"] == "<="
    with pytest.raises(ValueError, match="op"):
        SLOSpec("bad", "x", "<", 1.0)
    names = [s.name for s in DEFAULT_LIVE_SLOS]
    assert set(s.name for s in DEFAULT_SERVE_SLOS) <= set(names)
    assert {"staleness", "loss_divergence"} <= set(names)


def test_monitor_rejects_duplicate_slo_names_and_bad_window():
    dup = (SLOSpec("a", "rps", ">=", 1.0), SLOSpec("a", "p99_s", "<=", 1.0))
    with pytest.raises(ValueError, match="duplicate"):
        HealthMonitor(dup)
    with pytest.raises(ValueError, match="window_s"):
        HealthMonitor(window_s=0)


# ---------------------------------------------------------------------------
# EWMA drift watch
# ---------------------------------------------------------------------------


def test_drift_clean_descending_curve_never_flags():
    """The committed BENCH_live convergence curves (restarting per cell)
    must stay clean — the monitored benchmark replays exactly these."""
    w = EWMADrift()
    cell = [354.891357, 258.262146, 241.981476, 244.043549, 229.709702]
    for _ in range(4):                      # four cells share one watch
        for v in cell:
            w.observe(v)
        assert not w.diverging
    assert w.status in ("ok", "plateau")


def test_drift_flags_rising_and_nonfinite_loss():
    w = EWMADrift()
    for v in (1.0, 2.0, 3.0):
        w.observe(v)
    assert w.diverging and w.status == "diverging"

    blown = EWMADrift()
    blown.observe(1.0)
    blown.observe(float("nan"))
    assert blown.diverging and not blown.plateaued

    flat = EWMADrift()
    for _ in range(6):
        flat.observe(5.0)
    assert flat.plateaued and not flat.diverging
    assert flat.status == "plateau"

    with pytest.raises(ValueError, match="alpha"):
        EWMADrift(alpha_fast=0.1, alpha_slow=0.5)


# ---------------------------------------------------------------------------
# windows, rolls, breach emission
# ---------------------------------------------------------------------------


def test_windows_roll_on_clock_and_emit_breach_counters_and_instants(traced):
    now = [0.0]
    mon = HealthMonitor(
        (SLOSpec("lat", "p99_s", "<=", 0.01),
         SLOSpec("tput", "rps", ">=", 1000.0)),
        window_s=1.0, clock=lambda: now[0])
    mon.on_flush(n=4, padded=8, queue_depth=2, latencies=[0.001] * 4)
    now[0] = 2.0
    # the next hook call rolls window 0 lazily before recording
    mon.on_flush(n=4, padded=8, queue_depth=5, latencies=[0.5] * 4)
    assert mon.windows == 1
    w0 = mon.history[0]
    assert w0["n_scored"] == 4 and w0["batch_fill"] == pytest.approx(0.5)
    assert w0["breaches"] == ["tput"]       # 4 req / 2 s, p99 fine
    now[0] = 2.5
    w1 = mon.roll()
    assert sorted(w1["breaches"]) == ["lat", "tput"]
    assert mon.total_breaches == 3
    assert mon.breaches == {"lat": 1, "tput": 2}

    snap = metrics.snapshot()
    assert snap["counters"]["slo.breaches"] == 3
    assert snap["counters"]["slo.breach.lat"] == 1
    assert snap["counters"]["slo.breach.tput"] == 2
    assert snap["counters"]["slo.windows"] == 2
    assert snap["gauges"]["health.p99_s"] >= 0.01

    ft = export.read_trace(trace.current_path())
    breaches = [i for i in ft.instants if i["name"] == "slo.breach"]
    assert len(breaches) == 3
    assert {b["args"]["slo"] for b in breaches} == {"lat", "tput"}
    assert all("threshold" in b["args"] for b in breaches)

    s = mon.summary()
    assert s["total_breaches"] == 3 and s["windows"] == 2
    assert s["cumulative"]["count"] == 8
    assert "tput" in mon.table()


def test_empty_windows_never_fabricate_breaches():
    now = [0.0]
    mon = HealthMonitor(DEFAULT_SERVE_SLOS, window_s=1.0,
                        clock=lambda: now[0])
    for t in (5.0, 10.0, 100.0):            # long idle stretches
        now[0] = t
        assert mon.roll() is None
    assert mon.windows == 0 and mon.total_breaches == 0
    # a real observation after the idle gap still lands in a fresh window
    mon.on_flush(n=2, padded=2, queue_depth=0, latencies=[0.001, 0.002])
    now[0] = 101.0
    w = mon.roll()
    assert w["n_scored"] == 2 and w["breaches"] == []


def test_loss_only_window_skips_latency_slos():
    now = [0.0]
    mon = HealthMonitor(DEFAULT_LIVE_SLOS, window_s=1.0,
                        clock=lambda: now[0])
    mon.observe_loss(10.0)
    mon.observe_loss(9.0)
    w = mon.roll()
    # no scoring: p99/rps/staleness have no value -> skipped, not breached
    assert w["p99_s"] is None and w["rps"] is None
    assert w["breaches"] == [] and w["evaluated"] == 1      # loss_divergence
    assert w["loss"] == 9.0 and w["loss_status"] == "ok"


def test_history_is_bounded():
    now = [0.0]
    mon = HealthMonitor((), window_s=1.0, clock=lambda: now[0],
                        max_windows=4)
    for i in range(10):
        mon.observe_loss(float(i))
        now[0] += 2.0
        mon.roll()
    assert mon.windows == 10 and len(mon.history) == 4
    assert mon.history[-1]["window"] == 9


# ---------------------------------------------------------------------------
# engine + live hooks
# ---------------------------------------------------------------------------


def _engine(**kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("queue_depth", 4)
    kw.setdefault("flush_deadline_s", 0.0)
    return GLMScoreEngine(TASK, np.zeros(16, np.float32), ell_width=2, **kw)


def test_engine_flush_and_reject_report_into_windows():
    mon = HealthMonitor(DEFAULT_SERVE_SLOS, window_s=3600.0)
    eng = _engine()
    assert mon.attach_engine(eng) is mon    # chainable attach
    assert eng.monitor is mon
    for rid in range(6):                    # queue_depth 4: two shed
        eng.try_admit(ScoreRequest(rid, np.ones(2), np.zeros(2, int)))
    eng.drain()
    w = mon.roll()
    assert w["n_scored"] == 4 and w["rejected"] == 2
    assert w["flushes"] == 1 and w["batch_fill"] == 1.0
    assert w["p99_s"] > 0 and w["breaches"] == []


def test_engine_fault_stall_injects_latency_breach():
    mon = HealthMonitor((SLOSpec("lat", "p99_s", "<=", 0.02),),
                        window_s=3600.0)
    eng = _engine(fault_stall_s=0.05)
    mon.attach_engine(eng)
    eng.try_admit(ScoreRequest(0, np.ones(2), np.zeros(2, int)))
    eng.flush()
    w = mon.roll()
    assert w["p99_s"] >= 0.05 and w["breaches"] == ["lat"]
    with pytest.raises(ValueError, match="fault_stall_s"):
        _engine(fault_stall_s=-1.0)


def _live_stack(merge_every=2, every_merges=1):
    stream = SyntheticStream(n_batch=8, d=32, seed=0)
    cfg = LiveConfig(task=TASK, replicas=2, step_size=0.1,
                     merge_every=merge_every, compress=False)
    lrn = LiveLearner(cfg, stream)
    eng = GLMScoreEngine(TASK, np.zeros(32, np.float32),
                         ell_width=stream.ell_width, max_batch=4)
    pub = SnapshotPublisher(eng, every_merges=every_merges).attach(lrn)
    return lrn, pub, eng


def test_watch_live_staleness_stays_under_bound_when_publishing():
    lrn, pub, eng = _live_stack(merge_every=2, every_merges=1)
    mon = HealthMonitor(DEFAULT_LIVE_SLOS, window_s=3600.0)
    mon.watch_live(lrn, pub)
    assert lrn.monitor is mon and pub.monitor is mon
    lrn.run(8)                              # merges at 2,4,6,8 -> publishes
    w = mon.roll()
    assert w["staleness_bound"] == 2
    assert w["staleness_steps"] <= 2 and w["publishes"] == 4
    assert w["staleness_ratio"] <= 1.0
    assert "staleness" not in w["breaches"]


def test_stalled_publisher_breaches_against_bound_captured_at_attach():
    lrn, pub, eng = _live_stack(merge_every=2, every_merges=1)
    mon = HealthMonitor(DEFAULT_LIVE_SLOS, window_s=3600.0)
    mon.watch_live(lrn, pub)
    lrn.run(2)                              # first publish at merge 1
    assert pub.publishes >= 1
    pub.every_merges = 10 ** 9              # injected stall
    lrn.run(10)                             # staleness climbs to ~10 >> 2
    w = mon.roll()
    assert w["staleness_bound"] == 2        # attach-time bound, not relaxed
    assert w["staleness_steps"] > 2 and w["staleness_ratio"] > 1.0
    assert "staleness" in w["breaches"]
    assert mon.breaches.get("staleness", 0) >= 1


def test_watch_live_before_first_publish_skips_staleness():
    lrn, pub, eng = _live_stack(merge_every=4, every_merges=1)
    mon = HealthMonitor(DEFAULT_LIVE_SLOS, window_s=3600.0)
    mon.watch_live(lrn, pub)
    lrn.run(2)                              # no merge yet -> no publish
    mon.observe_loss(1.0)                   # make the window non-empty
    w = mon.roll()
    assert w["staleness_steps"] is None and w["staleness_ratio"] is None
    assert "staleness" not in w["breaches"]


# ---------------------------------------------------------------------------
# CLI + sidecar persistence
# ---------------------------------------------------------------------------


def test_monitor_cli_clean_run_exits_zero(metrics_only, capsys):
    mon = HealthMonitor(DEFAULT_SERVE_SLOS, window_s=3600.0)
    eng = _engine()
    mon.attach_engine(eng)
    eng.try_admit(ScoreRequest(0, np.ones(2), np.zeros(2, int)))
    eng.drain()
    mon.roll()
    assert metrics.flush(0) is not None     # sidecar, no tracing
    assert not list(metrics_only.glob("trace-*.jsonl"))

    assert monitor_mod.main([str(metrics_only), "--check"]) == 0
    out = capsys.readouterr().out
    assert "breaches=0" in out.replace(" ", "") or "0 breach(es)" in out

    capsys.readouterr()
    assert monitor_mod.main([str(metrics_only), "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["total_breaches"] == 0 and doc["windows"] == 1
    assert doc["files"][0]["health"]["p99_s"] > 0


def test_monitor_cli_check_exit_counts_breaches(traced, capsys):
    now = [0.0]
    mon = HealthMonitor((SLOSpec("lat", "p99_s", "<=", 1e-9),),
                        window_s=1.0, clock=lambda: now[0])
    for i in range(3):
        mon.on_flush(n=1, padded=1, queue_depth=0, latencies=[0.01])
        now[0] += 2.0
        mon.roll()
    assert metrics.flush(0) is not None
    assert monitor_mod.main([str(traced), "--check"]) == 3
    out = capsys.readouterr().out
    assert "BREACH lat" in out
    capsys.readouterr()
    assert monitor_mod.main([str(traced), "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["breaches"] == {"lat": 3}
    assert doc["trace_breach_events"] == 3  # instants on the timeline


def test_monitor_cli_no_sidecars_is_a_check_failure(tmp_path, capsys):
    assert monitor_mod.main([str(tmp_path), "--check"]) == 1
    assert "no metrics sidecars" in capsys.readouterr().err
    assert monitor_mod.main([str(tmp_path)]) == 0   # report mode: not fatal


def test_metrics_env_alone_enables_sidecar_and_flush_rate_limit(
        metrics_only):
    assert not trace.enabled() and metrics.enabled()
    metrics.counter("x.hits").inc()
    p = metrics.flush(0)
    assert p is not None and p.parent == metrics_only
    assert p.name.startswith("metrics-") and "main" in p.name
    assert json.loads(p.read_text())["counters"]["x.hits"] == 1
    # rate limit: an immediate second flush under the floor is skipped
    assert metrics.flush(3600.0) is None
    assert metrics.flush(0) is not None     # floor 0 always writes


def test_metrics_disabled_flush_is_noop(tmp_path, monkeypatch):
    monkeypatch.delenv(trace.ENV_TRACE, raising=False)
    monkeypatch.delenv(metrics.ENV_METRICS, raising=False)
    monkeypatch.setenv(trace.ENV_TRACE_DIR, str(tmp_path))
    trace.refresh()
    metrics.reset()
    metrics.counter("x").inc()
    assert not metrics.enabled()
    assert metrics.flush(0) is None
    assert list(tmp_path.iterdir()) == []
    metrics.reset()
