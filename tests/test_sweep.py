"""Distributed sweep scheduler: planner, merge layer, workers, executor.

The acceptance contract of the subsystem (ISSUE 4):
* the planner never splits a stack group across workers;
* cache merges are idempotent on identical payloads and raise — listing
  every key — on same-key/different-payload conflicts;
* a killed worker's partial cache survives, its unfinished keys are
  requeued, and bounded retries end in ``ShardFailure``;
* a 2-worker sweep fills a cache from which a serial re-run writes a
  byte-identical ``BENCH_study.json`` (the CI sweep-smoke invariant),
  with worker/shard/merge provenance in the JSONL sidecar only.
"""
import json

import numpy as np
import pytest

from repro.core import sgd
from repro.study import spec, store
from repro.study.runner import Runner, TrialResult
from repro.sweep import (LocalProcessExecutor, MergeConflict, Shard,
                         ShardFailure, merge_caches, plan)


def _trials(datasets=("covtype",), tasks=("lr",), steps=(1e-2, 1e-1),
            epochs=2, max_n=96):
    return list(spec.grid(
        [spec.DatasetSpec(d, max_n=max_n) for d in datasets], tasks,
        [sgd.SyncSGD()], steps=steps, epochs=epochs))


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------


def test_plan_colocates_stack_groups_and_partitions_trials():
    trials = _trials(datasets=("covtype", "w8a"), tasks=("lr", "svm"))
    shards = plan(trials, 2)
    assert {s.worker for s in shards} == {0, 1}
    # partition: every trial exactly once
    keys = [k for s in shards for k in s.keys]
    assert sorted(keys) == sorted(t.key for t in trials)
    # co-location: each stack group lives on exactly one worker
    owner = {}
    for s in shards:
        for t in s.trials:
            assert owner.setdefault(t.stack_key, s.worker) == s.worker
    # 4 groups over 2 workers balance 2/2 under the uniform weights
    assert sorted(len(s.trials) for s in shards) == [4, 4]


def test_plan_weighs_groups_by_data_volume():
    """One full-size dataset group must not share a worker with the
    fixture-sized groups: LPT balances on epochs x n x nnz, not on
    group count."""
    big = _trials(datasets=("covtype",), max_n=2048)          # 1 heavy group
    small = _trials(datasets=("w8a",), tasks=("lr", "svm"),
                    steps=(1e-3, 1e-2, 1e-1), max_n=64)       # 2 light groups
    shards = plan(big + small, 2)
    by_worker = {s.worker: {t.dataset.name for t in s.trials} for s in shards}
    assert by_worker[0] == {"covtype"}          # heavy group rides alone
    assert by_worker[1] == {"w8a"}


def test_plan_is_deterministic_and_drops_duplicates_and_empty_shards():
    trials = _trials()
    assert plan(trials, 2) == plan(trials, 2)
    # duplicates dispatch once
    assert sum(len(s.trials) for s in plan(trials + trials, 2)) == len(trials)
    # one stack group on 4 workers -> a single non-empty shard
    shards = plan(trials, 4)
    assert len(shards) == 1 and len(shards[0].trials) == len(trials)
    with pytest.raises(ValueError, match="workers"):
        plan(trials, 0)


def test_shard_round_trips_through_dict():
    shard = plan(_trials(), 1)[0]
    restored = Shard.from_dict(json.loads(json.dumps(shard.to_dict())))
    assert restored == shard
    with pytest.raises(ValueError, match="schema"):
        Shard.from_dict({"schema": -1, "worker": 0, "trials": []})


# ---------------------------------------------------------------------------
# merge layer
# ---------------------------------------------------------------------------


def _write_cache(root, entries: dict):
    root.mkdir(parents=True, exist_ok=True)
    for key, payload in entries.items():
        (root / f"{key}.json").write_text(spec.canonical_json(payload))


def test_merge_unions_and_is_idempotent_on_identical_payloads(tmp_path):
    a, b, dest = tmp_path / "a", tmp_path / "b", tmp_path / "dest"
    _write_cache(a, {"k1": {"x": 1}, "k2": {"x": 2}})
    _write_cache(b, {"k2": {"x": 2}, "k3": {"x": 3}})   # k2 identical overlap
    rep = merge_caches([a, b], dest)
    assert (rep.merged, rep.identical, rep.sources) == (3, 1, 2)
    assert sorted(p.stem for p in dest.glob("*.json")) == ["k1", "k2", "k3"]
    # re-merging the same roots is a no-op (everything byte-matches dest)
    rep2 = merge_caches([a, b], dest)
    assert (rep2.merged, rep2.identical) == (0, 4)
    # missing / empty sources are fine (dead worker with no output)
    rep3 = merge_caches([tmp_path / "nope"], dest)
    assert (rep3.merged, rep3.identical) == (0, 0)


def test_merge_conflict_raises_with_every_key_and_writes_nothing(tmp_path):
    a, b, dest = tmp_path / "a", tmp_path / "b", tmp_path / "dest"
    _write_cache(a, {"k1": {"x": 1}, "k2": {"x": 2}, "ok": {"x": 0}})
    _write_cache(b, {"k1": {"x": 9}, "k2": {"x": 8}})   # both keys conflict
    with pytest.raises(MergeConflict) as ei:
        merge_caches([a, b], dest)
    assert sorted(ei.value.keys) == ["k1", "k2"]
    assert "k1" in str(ei.value) and "k2" in str(ei.value)
    assert not dest.exists()                 # all-or-nothing: nothing written
    # conflicts against the destination are caught too
    _write_cache(dest, {"k1": {"x": 1}})
    with pytest.raises(MergeConflict) as ei:
        merge_caches([b], dest)
    assert "k1" in ei.value.keys


def test_merge_skips_tmp_files(tmp_path):
    a, dest = tmp_path / "a", tmp_path / "dest"
    _write_cache(a, {"k1": {"x": 1}})
    (a / ".k9.tmp.123").write_text("partial write")
    rep = merge_caches([a], dest)
    assert rep.merged == 1
    assert [p.stem for p in dest.glob("*.json")] == ["k1"]


# ---------------------------------------------------------------------------
# worker protocol + executor (subprocess-based; kept small)
# ---------------------------------------------------------------------------


def test_runner_rejects_executor_without_cache():
    with pytest.raises(ValueError, match="cache_dir"):
        Runner(executor=LocalProcessExecutor(workers=2))
    # post-construction attachment (benchmarks.run --workers style) is
    # validated too
    r = Runner()
    with pytest.raises(ValueError, match="cache_dir"):
        r.executor = LocalProcessExecutor(workers=2)


def test_two_worker_sweep_reproduces_serial_store_bytes(tmp_path):
    """The acceptance property behind CI's sweep-smoke job, in miniature:
    a 2-worker sweep fills the canonical cache; a serial re-run over that
    cache writes byte-identical BENCH_study.json — and the sidecar holds
    the worker/shard/merge provenance, never the snapshot."""
    trials = _trials(datasets=("covtype", "w8a"))

    def sweep(path, executor):
        st = store.StudyStore(path, jsonl_path=tmp_path / "runs.jsonl")
        Runner(cache_dir=tmp_path / "cache", store=st,
               executor=executor).run(trials)
        st.record_claims([], checked_modules=["mini"])
        return st.write().read_text()

    ex = LocalProcessExecutor(workers=2, work_dir=tmp_path / "work")
    first = sweep(tmp_path / "a.json", ex)
    second = sweep(tmp_path / "b.json", None)       # serial, warm cache
    assert first == second
    assert "sweep_shard" not in first               # provenance not in JSON

    events = [json.loads(line)
              for line in (tmp_path / "runs.jsonl").read_text().splitlines()]
    shard_events = [e for e in events if e.get("event") == "sweep_shard"]
    merge_events = [e for e in events if e.get("event") == "sweep_merge"]
    assert {e["worker"] for e in shard_events} == {0, 1}
    assert all(e["returncode"] == 0 for e in shard_events)
    assert sorted(k for e in shard_events for k in e["completed"]) == \
        sorted(t.key for t in trials)
    [merge] = merge_events
    assert merge["merged"] == len(trials) and merge["workers"] == 2
    # the serial warm run dispatched nothing
    assert sum(e.get("event") == "sweep_merge" for e in events) == 1


def test_worker_death_requeues_unfinished_and_keeps_partial_cache(tmp_path):
    """A worker killed mid-shard (fault injection: exit 17 after its first
    stack group) leaves the finished trials durably cached; the executor
    requeues exactly the unfinished keys, the retry completes them, and
    the provenance events record the whole story."""
    trials = _trials(tasks=("lr", "svm"))    # 2 stack groups x 2 trials
    st = store.StudyStore(tmp_path / "out.json",
                          jsonl_path=tmp_path / "runs.jsonl")
    ex = LocalProcessExecutor(
        workers=1, work_dir=tmp_path / "work",
        worker_args=("--fault-after", "2",
                     "--fault-flag", str(tmp_path / "flag")))
    out = Runner(cache_dir=tmp_path / "cache", store=st, executor=ex) \
        .run(trials)
    st.write()
    assert all(np.isfinite(r.final_loss) for r in out)
    assert sorted(p.stem for p in (tmp_path / "cache").glob("*.json")) == \
        sorted(t.key for t in trials)

    events = [json.loads(line)
              for line in (tmp_path / "runs.jsonl").read_text().splitlines()]
    shard_events = [e for e in events if e.get("event") == "sweep_shard"]
    assert [e["attempt"] for e in shard_events] == [0, 1]
    died, retried = shard_events
    assert died["returncode"] == 17
    assert len(died["completed"]) == 2      # first stack group survived
    assert sorted(died["requeued"]) == sorted(retried["keys"])
    assert retried["returncode"] == 0
    # the retry ran exactly the keys the dead worker left unfinished —
    # partial results are preserved, never recomputed
    assert set(retried["keys"]) == \
        {t.key for t in trials} - set(died["completed"])
    [merge] = [e for e in events if e.get("event") == "sweep_merge"]
    assert merge["retries"] == 1
    assert merge["merged"] == len(trials)


def test_sigkilled_worker_keeps_readable_partial_metrics_sidecar(
        tmp_path, monkeypatch):
    """SIGKILL durability (satellite): ``--fault-mode kill`` bypasses
    atexit entirely, so the only sidecar bytes a dead worker leaves are
    the per-stack-group ``metrics.flush()`` writes — which must be a
    complete, readable snapshot (atomic tmp+rename), tagged with the
    attempt (``shard0a0``) the executor assigned via the trace-tag env
    even though tracing is off."""
    from repro.obs import metrics, trace

    monkeypatch.delenv(trace.ENV_TRACE, raising=False)
    monkeypatch.setenv(metrics.ENV_METRICS, "1")
    monkeypatch.setenv(trace.ENV_TRACE_DIR, str(tmp_path / "tracedir"))
    trace.refresh()

    trials = _trials(tasks=("lr", "svm"))    # 2 stack groups x 2 trials
    st = store.StudyStore(tmp_path / "out.json",
                          jsonl_path=tmp_path / "runs.jsonl")
    ex = LocalProcessExecutor(
        workers=1, work_dir=tmp_path / "work",
        worker_args=("--fault-after", "2", "--fault-mode", "kill",
                     "--fault-flag", str(tmp_path / "flag")))
    out = Runner(cache_dir=tmp_path / "cache", store=st, executor=ex) \
        .run(trials)
    st.write()
    assert len(out) == len(trials)          # retry completed the shard

    events = [json.loads(line)
              for line in (tmp_path / "runs.jsonl").read_text().splitlines()]
    shard_events = [e for e in events if e.get("event") == "sweep_shard"]
    died, retried = shard_events
    assert died["returncode"] == -9         # a real SIGKILL, not exit(17)
    assert retried["returncode"] == 0

    # the killed attempt's partial sidecar survived and parses cleanly
    killed = sorted((tmp_path / "tracedir").glob("metrics-shard0a0-*.json"))
    assert killed, "SIGKILLed worker left no metrics sidecar"
    snap = json.loads(killed[0].read_text())
    assert snap["schema"] == metrics.METRICS_SCHEMA
    assert snap["counters"]                 # the first group's activity
    # no half-written tmp files anywhere (atomic rename discipline)
    assert not list((tmp_path / "tracedir").glob("*.tmp*"))


def test_retries_exhausted_raises_but_merges_completed_trials(tmp_path):
    """Exhausted retries fail the sweep — after merging what did finish
    and recording provenance, so the next attempt resumes from the
    canonical cache and the operator can see which worker died."""
    trials = _trials(tasks=("lr", "svm"))    # 2 stack groups x 2 trials
    st = store.StudyStore(tmp_path / "out.json",
                          jsonl_path=tmp_path / "runs.jsonl")
    ex = LocalProcessExecutor(workers=1, work_dir=tmp_path / "work",
                              max_retries=0,
                              worker_args=("--fault-after", "2"))
    with pytest.raises(ShardFailure, match="unfinished"):
        Runner(cache_dir=tmp_path / "cache", store=st,
               executor=ex).run(trials)
    # the first stack group completed before the injected death and was
    # merged despite the failure; the scratch dir is kept for post-mortem
    assert len(list((tmp_path / "cache").glob("*.json"))) == 2
    assert list((tmp_path / "work").glob("sweep-*"))
    # the failed sweep is still attributable: events survived the raise
    st.write()
    events = [json.loads(line)
              for line in (tmp_path / "runs.jsonl").read_text().splitlines()]
    [died] = [e for e in events if e.get("event") == "sweep_shard"]
    assert died["returncode"] == 17 and len(died["completed"]) == 2
    assert any(e.get("event") == "sweep_merge" for e in events)


def test_executor_cleans_scratch_after_success(tmp_path):
    trials = _trials(steps=(1e-2,))
    ex = LocalProcessExecutor(workers=1, work_dir=tmp_path / "work")
    Runner(cache_dir=tmp_path / "cache", executor=ex,
           dispatch_min_groups=1).run(trials)
    assert list((tmp_path / "work").glob("sweep-*")) == []


def test_single_stack_group_stays_in_process(tmp_path):
    """One stack group cannot parallelize: by default the runner executes
    it locally instead of paying a worker cold start (so --workers is
    never slower than serial on single-grid call sites)."""

    class _MustNotDispatch:
        def execute(self, trials, cache, *, stack=True):
            raise AssertionError("single-group dispatch reached executor")

    trials = _trials()      # one 2-step stack group
    out = Runner(cache_dir=tmp_path / "cache",
                 executor=_MustNotDispatch()).run(trials)
    assert [r.cached for r in out] == [False, False]


def test_dispatch_forwards_the_runners_stack_flag(tmp_path):
    """Runner(stack=False) must cache unstacked payloads even when the
    trials execute in worker subprocesses."""
    trials = _trials()      # one 2-step stack group
    ex = LocalProcessExecutor(workers=1, work_dir=tmp_path / "work")
    out = Runner(cache_dir=tmp_path / "unstacked", stack=False,
                 executor=ex, dispatch_min_groups=1).run(trials)
    assert [r.stacked for r in out] == [False, False]
    out = Runner(cache_dir=tmp_path / "stacked", executor=ex,
                 dispatch_min_groups=1).run(trials)
    assert [r.stacked for r in out] == [True, True]


def test_dispatched_results_match_in_process_results(tmp_path):
    """Worker subprocesses compute the same numbers the in-process runner
    does: same specs, same seeds, same engine."""
    trials = _trials(steps=(1e-2,), epochs=3)
    ex = LocalProcessExecutor(workers=1, work_dir=tmp_path / "work")
    dispatched = Runner(cache_dir=tmp_path / "cache", executor=ex,
                        dispatch_min_groups=1).run(trials)
    local = Runner().run(trials)
    for a, b in zip(dispatched, local):
        np.testing.assert_allclose(a.losses, b.losses, rtol=1e-5, atol=1e-6)
    # and the dispatched payloads round-trip as TrialResults from the cache
    payload = json.loads(
        (tmp_path / "cache" / f"{trials[0].key}.json").read_text())
    restored = TrialResult.from_dict(payload)
    np.testing.assert_array_equal(restored.losses, dispatched[0].losses)
