"""Unit tests for the per-device block/grid autotuner (repro.kernels.tune)."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels  # noqa: F401  — registers all families
from repro.kernels import common, tune


# ---------------------------------------------------------------------------
# Shape classes and cache keys
# ---------------------------------------------------------------------------


def test_shape_class_buckets_to_powers_of_two():
    a = tune.shape_class({"n": 96, "d": 50, "dtype": "float32"})
    b = tune.shape_class({"n": 128, "d": 64, "dtype": "float32"})
    assert a == b == {"d": 64, "dtype": "float32", "n": 128}


def test_shape_class_passes_non_integers_through():
    sc = tune.shape_class({"sparse": True, "dtype": "bfloat16", "n": 0})
    assert sc == {"dtype": "bfloat16", "n": 0, "sparse": True}
    assert sc["sparse"] is True  # bools survive as bools, not buckets


def test_cache_key_separates_kernels_backends_and_classes(tmp_path):
    cache = tune.TuneCache(tmp_path)
    info = {"n": 64, "d": 32, "dtype": "float32"}
    base = cache.key("glm_grad", common.REFERENCE, info)
    assert cache.key("glm_grad", common.REFERENCE, {"n": 96, "d": 50,
                                                    "dtype": "float32"}) \
        != base  # different bucket for n (128 vs 64)
    assert cache.key("glm_grad", common.PALLAS_INTERPRET, info) != base
    assert cache.key("glm_sgd", common.REFERENCE, info) != base
    # same bucket -> same key
    assert cache.key("glm_grad", common.REFERENCE,
                     {"n": 33, "d": 17, "dtype": "float32"}) \
        == cache.key("glm_grad", common.REFERENCE,
                     {"n": 64, "d": 32, "dtype": "float32"})


def test_cache_round_trip_is_canonical_json(tmp_path):
    cache = tune.TuneCache(tmp_path)
    payload = {"b": 2, "a": 1}
    cache.put("k1", payload)
    raw = (tmp_path / "k1.json").read_text()
    assert raw == '{"a":1,"b":2}'  # sorted keys, no whitespace
    assert cache.get("k1") == payload
    assert cache.get("nope") is None


# ---------------------------------------------------------------------------
# Candidate grids
# ---------------------------------------------------------------------------


def test_micro_batch_candidates_divide_n():
    cands = tune.TUNABLES["glm_sgd"].candidates({"n": 96})
    mbs = [c["micro_batch"] for c in cands]
    assert mbs and all(96 % m == 0 for m in mbs)
    # prime n still yields the trivial candidate
    assert tune.TUNABLES["glm_sgd"].candidates({"n": 97}) \
        == ({"micro_batch": 1},)


def test_attn_candidates_divide_both_sequences():
    cands = tune.TUNABLES["flash_attn"].candidates({"seq_q": 64, "seq_k": 128})
    assert cands
    for c in cands:
        assert 64 % c["block_q"] == 0 and 128 % c["block_k"] == 0
    # unalignable sequences produce no candidates rather than bad ones
    assert tune.TUNABLES["flash_attn"].candidates({"seq_q": 7, "seq_k": 64}) \
        == ()


def test_row_block_and_sparse_candidates_are_aligned():
    for c in tune.TUNABLES["glm_grad"].candidates({"n": 200}):
        assert c["block_rows"] % common.SUBLANE == 0
    for c in tune.TUNABLES["glm_sparse"].candidates({"n": 64, "d": 700}):
        assert c["block_rows"] % common.SUBLANE == 0
        assert c["d_block"] % common.LANE == 0


# ---------------------------------------------------------------------------
# tune / lookup / consult
# ---------------------------------------------------------------------------


def test_tune_sweeps_candidates_and_caches_winner(tmp_path):
    cache = tune.TuneCache(tmp_path)
    info = {"n": 32, "d": 16, "dtype": "float32"}
    calls = []

    def run(**cfg):
        calls.append(cfg)
        return jnp.zeros(())

    rec = tune.tune("glm_grad", common.REFERENCE, info, run, cache=cache,
                    warmup=0, iters=1)
    assert rec["config"] in [c["config"] for c in rec["candidates"]]
    assert {"schema", "kernel", "backend", "device_kind", "shape_class",
            "config", "candidates"} <= set(rec)
    assert calls  # the sweep actually ran the kernel
    # second call short-circuits on the cache (no new timings)
    n_calls = len(calls)
    rec2 = tune.tune("glm_grad", common.REFERENCE, info, run, cache=cache)
    assert rec2 == rec and len(calls) == n_calls
    # and lookup returns only the declared tunable params
    cfg = tune.lookup("glm_grad", common.REFERENCE, info, cache=cache)
    assert set(cfg) == {"block_rows"}


def test_tune_unknown_kernel_raises(tmp_path):
    with pytest.raises(KeyError, match="no tunable parameters"):
        tune.tune("nope", common.REFERENCE, {}, lambda **k: None,
                  cache=tune.TuneCache(tmp_path))


def test_lookup_filters_foreign_config_keys(tmp_path):
    cache = tune.TuneCache(tmp_path)
    info = {"n": 32, "dtype": "float32"}
    key = cache.key("glm_sgd", common.REFERENCE, info)
    cache.put(key, {"config": {"micro_batch": 4, "evil_kwarg": 99}})
    assert tune.lookup("glm_sgd", common.REFERENCE, info, cache=cache) \
        == {"micro_batch": 4}


def test_consult_defaults_to_empty_without_cache_or_env(tmp_path, monkeypatch):
    monkeypatch.delenv(tune.ENV_AUTOTUNE, raising=False)
    cache = tune.TuneCache(tmp_path)
    info = {"n": 32, "d": 16, "dtype": "float32"}
    ran = []
    assert tune.consult("glm_grad", common.REFERENCE, info,
                        lambda **c: ran.append(c), cache=cache) == {}
    assert not ran  # no sweep unless REPRO_KERNEL_AUTOTUNE=1


def test_consult_tunes_on_miss_when_env_set(tmp_path, monkeypatch):
    monkeypatch.setenv(tune.ENV_AUTOTUNE, "1")
    cache = tune.TuneCache(tmp_path)
    info = {"n": 32, "d": 16, "dtype": "float32"}

    cfg = tune.consult("glm_grad", common.REFERENCE, info,
                       lambda **c: jnp.zeros(()), cache=cache)
    assert set(cfg) == {"block_rows"}
    # winner is now cached: a later consult needs no run closure at all
    assert tune.consult("glm_grad", common.REFERENCE, info, None,
                        cache=cache) == cfg


def test_consult_without_run_closure_is_lookup_only(tmp_path, monkeypatch):
    monkeypatch.setenv(tune.ENV_AUTOTUNE, "1")
    cache = tune.TuneCache(tmp_path)
    assert tune.consult("glm_grad", common.REFERENCE,
                        {"n": 8, "d": 8, "dtype": "float32"}, None,
                        cache=cache) == {}


def test_timeable_rejects_tracers():
    import jax

    x = jnp.ones((4,))
    assert tune.timeable(x)
    seen = []
    jax.jit(lambda a: seen.append(tune.timeable(a)) or a)(x)
    assert seen == [False]


# ---------------------------------------------------------------------------
# End-to-end: dispatch-time consultation applies the cached winner
# ---------------------------------------------------------------------------


def test_glm_grad_applies_cached_winner(tmp_path, monkeypatch, glm_data):
    """A cached tuning record changes the block size an unpinned call uses."""
    from repro.kernels.glm_grad import glm_grad
    from repro.kernels.glm_grad.ref import glm_grad_ref

    monkeypatch.setenv(tune.ENV_TUNE_DIR, str(tmp_path))
    monkeypatch.delenv(tune.ENV_AUTOTUNE, raising=False)
    X, y, w = glm_data(64, 24)
    info = {"dtype": "float32", "n": 64, "d": 24}
    b = common.resolve_backend("glm_grad", info=info)
    cache = tune.TuneCache(tmp_path)
    cache.put(cache.key("glm_grad", b, info),
              {"config": {"block_rows": 32}})
    out = glm_grad("lr", w, X, y)  # unpinned -> consults the cache
    np.testing.assert_allclose(out, glm_grad_ref("lr", w, X, y),
                               rtol=1e-4, atol=2e-3)


def test_autotune_env_tunes_and_reuses(tmp_path, monkeypatch, glm_data):
    from repro.kernels.glm_grad import glm_grad

    monkeypatch.setenv(tune.ENV_TUNE_DIR, str(tmp_path))
    monkeypatch.setenv(tune.ENV_AUTOTUNE, "1")
    X, y, w = glm_data(48, 20)
    glm_grad("lr", w, X, y)
    recs = list(tmp_path.glob("*.json"))
    assert len(recs) == 1
    rec = json.loads(recs[0].read_text())
    assert rec["kernel"] == "glm_grad" and rec["candidates"]
    # the second call must reuse the record, not re-time
    stamp = recs[0].stat().st_mtime_ns
    glm_grad("lr", w, X, y)
    assert recs[0].stat().st_mtime_ns == stamp
